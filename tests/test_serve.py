"""Serving engine differentials + paged-pool unit tests.

The engine's correctness contract is exactness: ``Engine.generate`` (batched
prefill, paged KV pool, continuous batching) must produce the same greedy
tokens as the token-at-a-time reference oracle (``serve.reference``), which
shares none of its machinery.  One config per architecture family pins that,
including mid-stream admission (a second request joining while the first is
decoding) and sliding-window ring wraparound with a window much smaller than
the sequence.  The pool tests pin the host-side invariants the device maths
relies on: disjoint allocation, garbage-block table entries, slot reuse
after release, admission rejection when full.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serve import (Engine, EngineConfig, PagedPool, PoolConfig,
                         blocks_needed, reference, stacked_params)

FAMILY_ARCHS = ["qwen3-4b", "gemma3-12b", "xlstm-125m"]


def _reduced(arch):
    return get_config(arch).reduced(n_layers=2, d_model=128, n_heads=4,
                                    vocab=512)


def _setup(arch, b=3, plen=12):
    cfg = _reduced(arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    prompts = np.asarray(jax.random.randint(key, (b, plen), 0, cfg.vocab),
                         np.int32)
    return cfg, params, prompts


# ---------------------------------------------------------------------------
# Paged pool (host-side accounting).
# ---------------------------------------------------------------------------

def test_pool_admit_release_reuse():
    pool = PagedPool(PoolConfig(rows=2, blocks=8, block_size=4, max_seq=32))
    a = pool.admit(3)
    assert a.row == 0 and len(a.block_ids) == 3
    assert list(pool.table[0, :3]) == list(a.block_ids)
    assert all(pool.table[0, 3:] == pool.pc.garbage)
    assert pool.free_blocks(0) == 5 and pool.free_rows(0) == 1
    b = pool.admit(5)
    assert set(a.block_ids).isdisjoint(b.block_ids)
    assert pool.can_admit(1) is None          # rows exhausted
    pool.release(a.row)
    assert pool.free_rows(0) == 1 and pool.free_blocks(0) == 3
    assert all(pool.table[a.row] == pool.pc.garbage)
    c = pool.admit(3)                         # released blocks come back
    assert set(c.block_ids) <= set(range(8)) - set(b.block_ids) \
        | set(a.block_ids)


def test_pool_rejects_when_full():
    pool = PagedPool(PoolConfig(rows=4, blocks=4, block_size=4, max_seq=32))
    assert pool.can_admit(5) is None          # more than the pool holds
    pool.admit(3)
    assert pool.can_admit(2) is None          # only 1 block left
    assert pool.can_admit(1) == 0
    with pytest.raises(RuntimeError):
        pool.admit(2)


def test_pool_shard_locality():
    pool = PagedPool(PoolConfig(rows=4, blocks=2, block_size=4, max_seq=16,
                                data=2))
    a = pool.admit(2)
    b = pool.admit(2)                         # shard 0 blocks gone -> shard 1
    assert {a.shard, b.shard} == {0, 1}
    assert b.row == b.shard * pool.pc.rows_local + b.row_local


def test_blocks_needed_per_family():
    bs, width = 4, 16
    qwen = _reduced("qwen3-4b")
    assert blocks_needed(qwen, bs, width, 10, 6) == 4      # ceil(16/4)
    xl = _reduced("xlstm-125m")
    assert blocks_needed(xl, bs, width, 10, 6) == 0        # SSM-only
    gm = _reduced("gemma3-12b")
    win = dataclasses.replace(
        gm, layers=tuple(dataclasses.replace(s, window=8)
                         for s in gm.layers))
    # ring = ceil(8/4)+1 = 3 caps the 4 blocks a 16-token request spans
    assert blocks_needed(win, bs, width, 10, 6) == 3


# ---------------------------------------------------------------------------
# Engine vs token-at-a-time oracle (exact greedy match).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_engine_matches_reference(arch):
    cfg, params, prompts = _setup(arch)
    max_new = 6
    ref = np.asarray(reference.generate(
        cfg, stacked_params(cfg, params), prompts, max_new,
        max_seq=prompts.shape[1] + max_new + 1))
    eng = Engine(cfg, params, EngineConfig(
        rows=4, blocks=32, block_size=4, max_seq=64, prefill_group=2,
        prefill_bucket=4))
    outs = eng.generate(list(prompts), max_new)
    for i in range(len(outs)):
        np.testing.assert_array_equal(outs[i], ref[i])
    s = eng.metrics.summary()
    assert s["completed"] == len(outs) and s["gen_tokens"] == 3 * max_new


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_mid_stream_admission(arch):
    """A request admitted while another is mid-decode must not perturb the
    in-flight rows, and must itself decode exactly."""
    cfg, params, prompts = _setup(arch, b=2)
    max_new = 6
    ref = np.asarray(reference.generate(
        cfg, stacked_params(cfg, params), prompts, max_new,
        max_seq=prompts.shape[1] + max_new + 1))
    eng = Engine(cfg, params, EngineConfig(
        rows=2, blocks=16, block_size=4, max_seq=32, prefill_group=1,
        prefill_bucket=4))
    r0 = eng.submit(prompts[0], max_new)
    eng.step()
    eng.step()                                 # r0 is two tokens in
    assert len(r0.generated) >= 2
    r1 = eng.submit(prompts[1], max_new)
    eng.run()
    np.testing.assert_array_equal(r0.tokens(), ref[0])
    np.testing.assert_array_equal(r1.tokens(), ref[1])


def test_ring_wraparound_sliding_window():
    """Window much smaller than the sequence: the paged ring must overwrite
    and mask exactly like the reference ring cache."""
    gm = _reduced("gemma3-12b")
    cfg = dataclasses.replace(
        gm, layers=tuple(dataclasses.replace(s, window=8)
                         for s in gm.layers))
    key = jax.random.PRNGKey(1)
    params = M.init_params(key, cfg)
    prompts = np.asarray(jax.random.randint(key, (2, 20), 0, cfg.vocab),
                         np.int32)
    max_new = 8                                # L=28 >> window=8
    ref = np.asarray(reference.generate(
        cfg, stacked_params(cfg, params), prompts, max_new, max_seq=32))
    eng = Engine(cfg, params, EngineConfig(
        rows=2, blocks=8, block_size=4, max_seq=32, prefill_group=2,
        prefill_bucket=4))
    outs = eng.generate(list(prompts), max_new)
    for i in range(2):
        np.testing.assert_array_equal(outs[i], ref[i])
    # ring admission: 28 tokens span 7 blocks but the ring caps at 3
    assert eng.requests[0].blocks_needed == 3


def test_queue_rejection_and_slot_reuse():
    """One row, tiny queue: continuous batching must drain submissions
    through the same slot, reject the overflow, and stay exact."""
    cfg, params, prompts = _setup("qwen3-4b", b=4)
    max_new = 4
    ref = np.asarray(reference.generate(
        cfg, stacked_params(cfg, params), prompts, max_new,
        max_seq=prompts.shape[1] + max_new + 1))
    eng = Engine(cfg, params, EngineConfig(
        rows=1, blocks=8, block_size=4, max_seq=32, prefill_group=1,
        max_queue=3, prefill_bucket=4))
    reqs = [eng.submit(p, max_new) for p in prompts]
    # all four land before any tick drains: 3 queued, 4th over max_queue
    assert [r.status for r in reqs] == ["queued"] * 3 + ["rejected"]
    eng.run()
    for i in range(3):
        assert reqs[i].status == "done"
        np.testing.assert_array_equal(reqs[i].tokens(), ref[i])
    assert eng.metrics.rejected == 1 and eng.metrics.completed == 3
    assert eng.pool.active_rows == 0 and eng.pool.free_blocks(0) == 8


def test_submit_validation():
    cfg, params, _ = _setup("qwen3-4b")
    eng = Engine(cfg, params, EngineConfig(
        rows=1, blocks=4, block_size=4, max_seq=16, prefill_bucket=4))
    too_long = eng.submit(np.zeros(20, np.int32), 4)     # plen+new > max_seq
    assert too_long.status == "rejected"
    too_many = eng.submit(np.zeros(8, np.int32), 8)      # needs 4 blocks: ok
    assert too_many.status == "queued"


def test_metrics_survive_requests_straddling_reset():
    """``launch.serve`` resets metrics after warmup with requests still in
    flight; lifecycle edges for those rids must not KeyError.  Work counters
    (completed / gen_tokens) still advance — the tokens were produced in the
    post-reset window — but no percentile sample is recorded (its submit
    time belongs to the discarded window) and ``untracked`` counts the
    dropped edges."""
    from repro.serve.metrics import ServeMetrics

    t = [0.0]
    mx = ServeMetrics(clock=lambda: t[0])
    mx.submit("r1")
    t[0] = 1.0
    mx.admit("r1")
    mx.reset()                      # r1 still in flight
    t[0] = 2.0
    mx.first_token("r1")            # pre-reset rid: dropped edge, no crash
    t[0] = 3.0
    mx.finish("r1", n_gen=5)
    # post-reset request tracked normally alongside the straddler
    mx.submit("r2")
    t[0] = 4.0
    mx.first_token("r2")
    t[0] = 5.0
    mx.finish("r2", n_gen=7)
    s = mx.summary()
    assert s["untracked"] == 2              # r1's first_token + finish
    assert s["completed"] == 2 and s["gen_tokens"] == 12
    assert len(mx.ttft) == 1 and len(mx.latency) == 1
    assert s["ttft_ms"]["p50"] == 1000.0    # r2 only
    assert s["latency_ms"]["p50"] == 2000.0
