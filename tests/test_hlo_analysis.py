"""The dry-run's HLO cost model: dot flops, post-fusion bytes, collectives
— validated on hand-written HLO snippets and one real compiled module."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze, parse_module

HLO = """\
HloModule test

%fused_add (p0: f32[128,64], p1: f32[128,64]) -> f32[128,64] {
  %p0 = f32[128,64]{1,0} parameter(0)
  %p1 = f32[128,64]{1,0} parameter(1)
  ROOT %add.1 = f32[128,64]{1,0} add(%p0, %p1)
}

ENTRY %main (a: f32[128,256], w: f32[256,64]) -> f32[128,64] {
  %a = f32[128,256]{1,0} parameter(0)
  %w = f32[256,64]{1,0} parameter(1)
  %dot.1 = f32[128,64]{1,0} dot(%a, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,64]{1,0} all-reduce(%dot.1), replica_groups={}, to_apply=%fused_add
  ROOT %fusion.1 = f32[128,64]{1,0} fusion(%dot.1, %ar), kind=kLoop, calls=%fused_add
}
"""


def test_parse_and_costs_on_snippet():
    r = analyze(HLO)
    assert r["flops"] == 2 * 128 * 64 * 256
    assert r["collective_bytes"] == 128 * 64 * 4
    assert r["collectives"] == {"all-reduce": 128 * 64 * 4}
    # bytes: dot(res+a+w) + ar(res+dot) + fusion(res + dot + ar)
    b = (128 * 64 + 128 * 256 + 256 * 64) * 4 \
        + (128 * 64 + 128 * 64) * 4 + (128 * 64 * 3) * 4
    assert r["bytes"] == b


def test_on_real_compiled_module():
    def f(x, w1, w2):
        return jnp.tanh(x @ w1) @ w2

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w1 = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w2 = jax.ShapeDtypeStruct((256, 32), jnp.float32)
    comp = jax.jit(f).lower(x, w1, w2).compile()
    r = analyze(comp.as_text())
    expect = 2 * 64 * 128 * 256 + 2 * 64 * 256 * 32
    assert r["flops"] == pytest.approx(expect, rel=0.01)
    assert r["collective_bytes"] == 0


def test_while_trip_multiplier():
    hlo = """\
HloModule t

%body (p: f32[8]) -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %ar2 = f32[8]{0} all-reduce(%p), replica_groups={}
  ROOT %n = f32[8]{0} negate(%ar2)
}

%cond (p: f32[8]) -> pred[] {
  %p = f32[8]{0} parameter(0)
  ROOT %c = pred[] constant(true)
}

ENTRY %main (x: f32[8]) -> f32[8] {
  %x = f32[8]{0} parameter(0)
  ROOT %w = f32[8]{0} while(%x), condition=%cond, body=%body
}
"""
    r1 = analyze(hlo, while_trips=1)
    r5 = analyze(hlo, while_trips=5)
    assert r5["collective_bytes"] == 5 * r1["collective_bytes"]
    assert r1["n_while"] == 1


def test_model_flops_active_params():
    from repro.configs import get_config
    from repro.launch.dryrun import active_params
    # olmoe: ~1.3B active of ~6.9B total (64 experts, top-8)
    cfg = get_config("olmoe-1b-7b")
    act = active_params(cfg)
    assert 0.8e9 < act < 2.0e9
    dense = get_config("qwen3-4b")
    assert 3e9 < active_params(dense) < 6e9
