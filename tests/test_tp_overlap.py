"""Overlap-aware TP: decomposed ring collectives, the braided composite
executor, and the exposed-vs-hidden HLO classifier.

Differentials:
  * ``TPContext.ring_psum`` / ``start_psum``+``finish_psum`` == ``lax.psum``
    (bitwise at tp=2 — one commuted fp add per element; integer-exact at
    tp=4 where ring reassociation would otherwise round differently);
  * ``chunk_fwd_bwd_braided`` == ``chunk_fwd`` + ``chunk_bwd_act`` run
    sequentially, per architecture family (single-device degenerate ring)
    and under a real tp=2 shard_map group (qwen3 + MoE; the xlstm/mamba
    recurrent cores keep tp-local parameters by construction and are not
    reachable from the canonical unsharded ``init_params``, so the
    recurrent family is pinned on the degenerate path only);
  * the full SPMD pipeline with ``braid_tp=True`` == the naive monolithic
    program, per schedule kind and per slot lowering (fused + generic).

Multi-device cases run in subprocesses (device count must be fixed before
jax initializes)."""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.hlo_analysis import collective_overlap
from repro.models import model as M
from repro.tp.context import PendingPsum, TPContext

REPO = Path(__file__).resolve().parent.parent


def _run_sub(script: str, timeout: int = 900):
    r = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        cwd=str(REPO), env={"PYTHONPATH": str(REPO / "src"),
                            "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"},
        timeout=timeout)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


# ---------------------------------------------------------------------------
# PendingPsum / ring decomposition.
# ---------------------------------------------------------------------------

def test_pending_psum_no_axis_is_identity():
    tp = TPContext()
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 6))
    pend = tp.start_psum(x)
    assert isinstance(pend, PendingPsum)
    np.testing.assert_array_equal(np.asarray(tp.finish_psum(pend)),
                                  np.asarray(x))
    np.testing.assert_array_equal(np.asarray(tp.ring_psum(x)), np.asarray(x))


def test_start_fused_residual_no_axis():
    tp = TPContext()
    k = jax.random.PRNGKey(1)
    part = jax.random.normal(k, (2, 8))
    res = jax.random.normal(jax.random.fold_in(k, 1), (2, 8))
    pend = tp.start_fused_residual(part, res)
    np.testing.assert_allclose(np.asarray(tp.finish_psum(pend)),
                               np.asarray(part + res))


RING_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={t}"
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.tp.context import TPContext

    t = {t}
    mesh = Mesh(np.array(jax.devices()), ("model",))
    tp = TPContext(axis="model", size=t)
    tps = TPContext(axis="model", size=t, safe_ring=True)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (t, 3, 4 * t))       # divisible feature dim
    xi = jax.random.randint(key, (t, 3, 4 * t), -8, 8).astype(jnp.float32)
    xr = jax.random.normal(key, (t, 3, 4 * t + 1))  # ragged: fallback path

    @partial(shard_map, mesh=mesh, in_specs=P("model"),
             out_specs=(P(), P(), P(), P()), check_rep=False)
    def f(xs):
        x = xs[0]
        ref = jax.lax.psum(x, "model")
        ring = tp.ring_psum(x)
        pend = tp.start_psum(x)
        while not pend.done:
            pend.step()
        split = pend.finish()
        # safe_ring: one-hot psum hops, used under the pipeline's divergent
        # switch arms.
        safe = tps.ring_psum(x)
        return ref, ring, split, safe

    with mesh:
        ref, ring, split, safe = jax.device_get(f(x))
        refi, ringi, spliti, safei = jax.device_get(f(xi))
        refr, ringr, splitr, safer = jax.device_get(f(xr))
    if t == 2:     # one commuted fp add per element: bitwise
        assert np.array_equal(ref, ring) and np.array_equal(ref, split)
    else:          # reassociated; exact on integer-valued input
        assert np.array_equal(refi, ringi) and np.array_equal(refi, spliti)
        np.testing.assert_allclose(ring, ref, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(split, ref, rtol=1e-6, atol=1e-6)
    # safe_ring hops (one-hot psum) are value-identical to ppermute hops:
    # each hop's all-reduce has one non-zero contributor per output slot.
    assert np.array_equal(ring, safe) and np.array_equal(ringi, safei)
    assert np.array_equal(refr, ringr) and np.array_equal(refr, splitr), \\
        "ragged feature dim must fall back to monolithic psum"
    assert np.array_equal(refr, safer)
    print("OK", t)
""")


@pytest.mark.parametrize("t", [2, 4])
def test_ring_psum_matches_lax_psum(t):
    out = _run_sub(RING_SCRIPT.format(t=t))
    assert "OK" in out


# ---------------------------------------------------------------------------
# Braided composite chunk executor vs sequential chunks.
# ---------------------------------------------------------------------------

def _chunk_braid_case(arch, nl):
    """Single-device (degenerate PendingPsum) braided-vs-sequential chunk
    differential — must be bitwise."""
    cfg = get_config(arch).reduced(n_layers=2 * nl, d_model=64, n_heads=4,
                                   vocab=128)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    specs = cfg.layers
    f_lp, b_lp = params["blocks"][:nl], params["blocks"][nl:]
    fs, bs = specs[:nl], specs[nl:]
    assert [s.mixer for s in fs] == [s.mixer for s in bs]
    b, s = 2, 16
    x = jax.random.normal(key, (b, s, cfg.d_model))
    gy = jax.random.normal(jax.random.fold_in(key, 1), (b, s, cfg.d_model))
    rope = M._rope_for(cfg, s)
    tp = TPContext()

    xb, b_ctxs = M.chunk_fwd(b_lp, tp, x, rope, bs, cfg)
    y_ref, fc_ref = M.chunk_fwd(f_lp, tp, x, rope, fs, cfg)
    gx_ref, wt_ref, j_ref = M.chunk_bwd_act(b_lp, tp, b_ctxs, gy, bs, cfg)
    y, fc, gx, wt, j = M.chunk_fwd_bwd_braided(
        f_lp, x, b_lp, b_ctxs, gy, tp, rope, fs, cfg)

    for name, a, r in (("y", y, y_ref), ("gx", gx, gx_ref),
                       ("f_ctxs", fc, fc_ref), ("wtapes", wt, wt_ref),
                       ("joints", j, j_ref),
                       ("gw", M.chunk_bwd_weight(wt, bs),
                        M.chunk_bwd_weight(wt_ref, bs))):
        la, lr = jax.tree.leaves(a), jax.tree.leaves(r)
        assert len(la) == len(lr), (name, len(la), len(lr))
        for u, v in zip(la, lr):
            np.testing.assert_array_equal(np.asarray(u), np.asarray(v),
                                          err_msg=name)


def test_chunk_braided_dense():
    _chunk_braid_case("qwen3-4b", 2)


@pytest.mark.slow
def test_chunk_braided_moe():
    _chunk_braid_case("olmoe-1b-7b", 2)


@pytest.mark.slow
def test_chunk_braided_mamba_hybrid():
    _chunk_braid_case("jamba-1.5-large-398b", 2)


CHUNK_TP2_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.configs import get_config
    from repro.models import model as M
    from repro.pipeline.spmd import tp_specs
    from repro.tp.context import TPContext

    arch, nl = "{arch}", {nl}
    cfg = get_config(arch).reduced(n_layers=2 * nl, d_model=64, n_heads=4,
                                   vocab=128)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    specs = cfg.layers
    f_lp, b_lp = params["blocks"][:nl], params["blocks"][nl:]
    fs, bs = specs[:nl], specs[nl:]
    b, s = 2, 16
    x = jax.random.normal(key, (b, s, cfg.d_model))
    gy = jax.random.normal(jax.random.fold_in(key, 1), (b, s, cfg.d_model))
    rope = M._rope_for(cfg, s)
    mesh = Mesh(np.array(jax.devices()), ("model",))
    tp = TPContext(axis="model", size=2)

    def md(a, bb):
        la, lb = jax.tree.leaves(a), jax.tree.leaves(bb)
        assert len(la) == len(lb), (len(la), len(lb))
        if not la:
            return jnp.zeros(())
        return jnp.max(jnp.stack([jnp.max(jnp.abs(u - v))
                                  for u, v in zip(la, lb)]))

    @partial(shard_map, mesh=mesh,
             in_specs=(tp_specs(f_lp, "model", None),
                       tp_specs(b_lp, "model", None), P(), P()),
             out_specs=P(), check_rep=False)
    def run_both(f, bb_, x, gy):
        xb, b_ctxs = M.chunk_fwd(bb_, tp, x, rope, bs, cfg)
        y0, fc0 = M.chunk_fwd(f, tp, x, rope, fs, cfg)
        gx0, wt0, j0 = M.chunk_bwd_act(bb_, tp, b_ctxs, gy, bs, cfg)
        y1, fc1, gx1, wt1, j1 = M.chunk_fwd_bwd_braided(
            f, x, bb_, b_ctxs, gy, tp, rope, fs, cfg)
        gw0 = M.chunk_bwd_weight(wt0, bs)
        gw1 = M.chunk_bwd_weight(wt1, bs)
        return tp.pmax(jnp.stack([md(y0, y1), md(gx0, gx1), md(fc0, fc1),
                                  md(gw0, gw1), md(j0, j1)]))

    with mesh:
        diffs = jax.device_get(run_both(f_lp, b_lp, x, gy))
    assert float(diffs.max()) < 1e-5, diffs
    print("OK", arch, diffs.max())
""")


def test_chunk_braided_tp2_dense():
    """Real 2-rank ring: braided chunk == sequential chunks, bitwise at
    tp=2."""
    out = _run_sub(CHUNK_TP2_SCRIPT.format(arch="qwen3-4b", nl=1))
    assert "OK" in out


@pytest.mark.slow
def test_chunk_braided_tp2_moe():
    out = _run_sub(CHUNK_TP2_SCRIPT.format(arch="olmoe-1b-7b", nl=1))
    assert "OK" in out


# ---------------------------------------------------------------------------
# Full SPMD pipeline: braid_tp=True vs naive, per schedule and lowering.
# ---------------------------------------------------------------------------

BRAID_PIPE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.configs import get_config
    from repro.core.schedule import build
    from repro.models import model as M
    from repro.pipeline.spmd import build_pipeline_step, stack_stage_params

    kind, fuse = "{kind}", {fuse}
    p, tp_size, m = 2, 2, {m}
    tables, pl = build(kind, p, m)
    cfg = get_config("qwen3-4b").reduced(n_layers=pl.n_vs, d_model=64,
                                         n_heads=4, vocab=128)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    b, s = 2, 16
    ks = jax.random.split(key, m)
    tokens = jnp.stack([jax.random.randint(k, (b, s), 0, cfg.vocab)
                        for k in ks])
    labels = jnp.stack([jax.random.randint(k, (b, s), 0, cfg.vocab)
                        for k in ks])
    mesh = Mesh(np.array(jax.devices()).reshape(p, tp_size),
                ("stage", "model"))
    c0, c1, lvs = stack_stage_params(params, cfg, p, kind=pl.kind)
    stacked = (c0, c1, params["embed"], params["head"])
    outs = {{}}
    for braid in (False, True):
        step = build_pipeline_step(cfg, tables, pl, mesh, m, (b, s), stacked,
                                   model_axis="model", fuse_slots=fuse,
                                   braid_tp=braid)
        with mesh:
            outs[braid] = [np.asarray(x) for x in jax.tree.leaves(
                step(*stacked, tokens, labels))]
    err = max(float(np.max(np.abs(a - g)) / (np.max(np.abs(g)) + 1e-9))
              for a, g in zip(outs[True], outs[False]))
    loss_n, loss_b = outs[False][0], outs[True][0]
    assert abs(float(loss_b) - float(loss_n)) < 1e-5, (loss_n, loss_b)
    assert err < 1e-5, err
    print("OK", kind, "fused" if fuse else "generic", err)
""")


def _braid_pipe_case(kind, fuse, m=4, timeout=1800):
    out = _run_sub(BRAID_PIPE_SCRIPT.format(
        kind=kind, fuse="True" if fuse else "False", m=m), timeout=timeout)
    assert "OK" in out


def test_pipeline_braid_stp_fused():
    """vshape placement, segment-fused lowering (the paper's setting)."""
    _braid_pipe_case("stp", fuse=True)


@pytest.mark.slow
@pytest.mark.parametrize("kind,fuse", [
    ("gpipe", True),           # flat: no composite slots, braid is a no-op
    ("1f1b", True),
    ("1f1b", False),
    ("1f1b-i", True),          # parallel placement
    ("1f1b-i", False),
    ("zb-v", True),
    ("stp", False),
    ("stp-memeff", True),
])
def test_pipeline_braid_all_schedules(kind, fuse):
    """Braided == naive for every schedule kind on both slot lowerings."""
    _braid_pipe_case(kind, fuse, m=6)


# ---------------------------------------------------------------------------
# Pallas collective-matmul: fused ring == monolithic psum(x @ w).
# ---------------------------------------------------------------------------

COLLMM_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={t}"
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.kernels.ops import collective_matmul
    from repro.tp.context import TPContext

    t = {t}
    mesh = Mesh(np.array(jax.devices()), ("model",))
    tp = TPContext(axis="model", size=t)
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 2)
    x = jax.random.normal(ks[0], (4, 6, 8 * t))     # row-parallel input
    w = jax.random.normal(ks[1], (8 * t, 4 * t))    # k sharded, n tiled

    @partial(shard_map, mesh=mesh,
             in_specs=(P(None, None, "model"), P("model", None)),
             out_specs=(P(), P()), check_rep=False)
    def f(x, w):
        ref = tp.psum(jnp.einsum("bsk,kn->bsn",
                                 x.astype(jnp.float32),
                                 w.astype(jnp.float32)))
        out = collective_matmul(x, w, tp)
        return ref, out

    with mesh:
        ref, out = jax.device_get(f(x, w))
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)
    print("OK", t)
""")


@pytest.mark.parametrize("t", [2])
def test_collective_matmul_ring_matches_psum(t):
    out = _run_sub(COLLMM_SCRIPT.format(t=t))
    assert "OK" in out


@pytest.mark.slow
def test_collective_matmul_ring_matches_psum_tp4():
    out = _run_sub(COLLMM_SCRIPT.format(t=4))
    assert "OK" in out


# ---------------------------------------------------------------------------
# Exposed-vs-hidden HLO classifier.
# ---------------------------------------------------------------------------

_HLO_SAMPLE = """
HloModule m

ENTRY %main (p0: f32[8,16], p1: f32[16,4], p2: f32[16,4]) -> f32[8,4] {
  %p0 = f32[8,16] parameter(0)
  %p1 = f32[16,4] parameter(1)
  %p2 = f32[16,4] parameter(2)
  %ar0 = f32[8,16] all-reduce(%p0), replica_groups={{0,1},{2,3}}, to_apply=%add
  %indep = f32[8,4] dot(%p0, %p2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %dep = f32[8,4] dot(%ar0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar1 = f32[8,4] all-reduce(%dep), replica_groups={{0,2},{1,3}}, to_apply=%add
  ROOT %out = f32[8,4] add(%ar1, %indep)
}
"""


def test_collective_overlap_classifier():
    """ar0 (TP groups {0,1}/{2,3} at tp=2) has an independent dot inside
    its window -> hidden; ar1 (stage groups {0,2}/{1,3}) reaches the end of
    the computation with no independent dot after it -> exposed."""
    stats = collective_overlap(_HLO_SAMPLE, tp_size=2)
    assert stats["tp"]["n"] == 1 and stats["tp"]["n_hidden"] == 1
    assert stats["other"]["n"] == 1 and stats["other"]["n_exposed"] == 1
    assert stats["tp"]["exposed_share"] == 0.0
    assert stats["other"]["exposed_share"] == 1.0


def test_collective_overlap_start_done_pair():
    """Async -start collectives classify by the same window rule."""
    hlo = _HLO_SAMPLE.replace(
        "%ar0 = f32[8,16] all-reduce(%p0)",
        "%ar0 = f32[8,16] all-reduce-start(%p0)")
    stats = collective_overlap(hlo, tp_size=2)
    assert stats["tp"]["n"] == 1 and stats["tp"]["n_hidden"] == 1


_HLO_BARRIER = """
HloModule m

ENTRY %main (p0: f32[8,16], p1: f32[16,4], p2: f32[16,4]) -> f32[8,4] {
  %p0 = f32[8,16] parameter(0)
  %p1 = f32[16,4] parameter(1)
  %p2 = f32[16,4] parameter(2)
  %ar0 = f32[8,16] all-reduce(%p0), replica_groups={{0,1},{2,3}}, to_apply=%add
  %tie = (f32[8,16], f32[16,4]) tuple(%ar0, %p2)
  %bar = (f32[8,16], f32[16,4]) opt-barrier(%tie)
  %ring = f32[8,16] get-tuple-element(%bar), index=0
  %other = f32[16,4] get-tuple-element(%bar), index=1
  %indep = f32[16,4] dot(%p0, %other), lhs_contracting_dims={0}, rhs_contracting_dims={0}
  %dep = f32[8,4] dot(%ring, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %out = f32[8,4] dot(%dep, %indep), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_collective_overlap_barrier_elementwise():
    """An opt-barrier tying (ring state, partner state) — the braid's
    scheduling pin — is an element-wise identity in HLO dataflow: the
    partner's dot reads element 1, stays untainted by ar0 (element 0),
    and hides it.  A whole-value taint through the barrier would call ar0
    exposed (%indep would look dependent)."""
    stats = collective_overlap(_HLO_BARRIER, tp_size=2)
    assert stats["tp"]["n"] == 1 and stats["tp"]["n_hidden"] == 1
    assert stats["tp"]["exposed_share"] == 0.0
