"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED variant of its family (<=2-4 layers, d_model<=512,
<=4 experts) and runs one forward + one train step on CPU, asserting output
shapes and the absence of NaNs.  Decode-capable archs also run a serve step
with their cache type."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.data import DataConfig, make_batches
from repro.models import model as M
from repro.optim import OptConfig, adamw_init, adamw_update

ARCHS = sorted(ASSIGNED)


def reduced(name):
    extra = {"xlstm-125m": dict(n_layers=2),
             "jamba-1.5-large-398b": dict(n_layers=2),
             "gemma3-12b": dict(n_layers=2)}.get(name, {})
    return get_config(name).reduced(d_model=128, n_heads=4, vocab=256,
                                    **extra)


def batch_for(cfg, b=2, s=32, key=jax.random.PRNGKey(0)):
    dc = DataConfig(seq_len=s, global_batch=b, seed=3)
    return {k: jnp.asarray(v)
            for k, v in next(make_batches(cfg, dc, 1)).items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = reduced(arch)
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    stacked = {"embed": params["embed"],
               "blocks": M.stack_blocks(params["blocks"], M.period_of(cfg)),
               "head": params["head"]}
    batch = batch_for(cfg)
    x = M.forward(stacked, batch, cfg)
    assert x.shape == (2, 32, cfg.d_model)
    assert not np.any(np.isnan(np.asarray(x, np.float32)))

    oc = OptConfig(total_steps=10, warmup_steps=1)
    opt = adamw_init(stacked)
    loss, grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, batch, cfg))(stacked)
    assert np.isfinite(float(loss))
    p2, opt2, gn = adamw_update(stacked, grads, opt, oc)
    assert np.isfinite(float(gn))
    l2 = M.loss_fn(p2, batch, cfg)
    assert np.isfinite(float(l2))
    # at least some parameters moved
    moved = jax.tree.map(lambda a, b: float(np.max(np.abs(a - b))), stacked,
                         p2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).causal
                                  and get_config(a).frontend == "text"])
def test_serve_step(arch):
    cfg = reduced(arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    stacked = {"embed": params["embed"],
               "blocks": M.stack_blocks(params["blocks"], M.period_of(cfg)),
               "head": params["head"]}
    b = 2
    caches = M.init_caches_stacked(cfg, b, 64)
    tok = jnp.zeros((b, 1), jnp.int32)
    nxt, logits, caches = M.decode_step(stacked, caches, {"tokens": tok},
                                        jnp.int32(0), cfg)
    assert nxt.shape == (b,)
    assert logits.shape == (b, cfg.vocab)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ["qwen3-4b", "gemma3-12b", "xlstm-125m",
                                  "jamba-1.5-large-398b", "olmoe-1b-7b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode (incl. ring-buffer window caches and SSM
    states) reproduces the full forward's logits at every position.
    MoE archs need a no-drop capacity factor: the training path drops
    over-capacity tokens (by design), the decode path never drops."""
    import dataclasses
    cfg = reduced(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    stacked = {"embed": params["embed"],
               "blocks": M.stack_blocks(params["blocks"], M.period_of(cfg)),
               "head": params["head"]}
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)
    x = M.forward(stacked, {"tokens": toks}, cfg)
    from repro.models import units
    from repro.tp.context import TPContext
    x_ln, _ = units.prenorm_fwd(stacked["head"]["ln_f"], x, cfg)
    full_logits = jnp.einsum("bsd,dv->bsv", x_ln, stacked["head"]["w_lm"])

    caches = M.init_caches_stacked(cfg, b, 16)
    errs = []
    for pos in range(s):
        _, logits, caches = M.decode_step(
            stacked, caches, {"tokens": toks[:, pos:pos + 1]},
            jnp.int32(pos), cfg)
        errs.append(float(np.max(np.abs(
            np.asarray(logits) - np.asarray(full_logits[:, pos])))))
    assert max(errs) < 2e-2, errs   # fp32 vs bf16 cache tolerance


def test_config_fidelity():
    """The registry carries the exact assigned hyperparameters."""
    c = get_config("qwen3-moe-235b-a22b")
    assert (c.n_layers, c.d_model, c.n_heads, c.kv_heads) == (94, 4096, 64, 4)
    assert c.moe.num_experts == 128 and c.moe.top_k == 8
    assert c.vocab == 151936
    c = get_config("starcoder2-15b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (40, 6144, 24576,
                                                        49152)
    c = get_config("gemma3-12b")
    assert sum(1 for l in c.layers if l.window) == 40   # 5 of 6 local
    c = get_config("jamba-1.5-large-398b")
    assert sum(1 for l in c.layers if l.mixer == "attn") == 9   # 1 per 8
    assert sum(1 for l in c.layers if l.mlp == "moe") == 36
    c = get_config("hubert-xlarge")
    assert not c.causal and c.frontend == "embed" and c.vocab == 504
    c = get_config("xlstm-125m")
    kinds = {l.mixer for l in c.layers}
    assert kinds == {"slstm", "mlstm"}
