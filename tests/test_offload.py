"""§4.4 activation offload: simulator accounting, offload-aware IR
verification, and executor differentials.

The simulator half is pure-python replay checks: peak memory is monotone
non-increasing in α, only chunk-0 activations participate, the throughput
cost is exactly the per-F overhead the model charges, and the verifier
accepts every schedule's annotated table under the offload-aware
``memory_bound`` while rejecting the three malformed lifetime shapes
(double-offload, fetch-before-offload, missing FETCH).

The executor half runs the real SPMD lowering in subprocesses (device count
must be fixed before jax initializes) and pins the acceptance contract:
α=0 and α>0 produce identical results — the offload split/join is pure
data movement, so the diff bound is bitwise in practice and <1e-5 by
assertion — for both the segment-fused and generic lowerings, and through
the fused train step (AdamW state included)."""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core.schedule import SCHEDULES, build, memory_bound
from repro.core.simulator import (OffloadOp, ScheduleVerificationError,
                                  StageTimes, annotate_offload, simulate,
                                  strip_offload, verify_tables)

REPO = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# Simulator accounting.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", SCHEDULES)
def test_peak_mem_monotone_in_alpha(kind):
    p, m = 2, 6
    tables, pl = build(kind, p, m)
    t = StageTimes.uniform(pl.n_vs)
    peaks = [float(simulate(tables, pl, t, m,
                            offload_alpha=a).peak_mem.max())
             for a in (0.0, 0.2, 0.4, 0.6)]
    for lo, hi in zip(peaks[1:], peaks):
        assert lo <= hi + 1e-9, peaks
    # at least one chunk-0 activation is live at the peak, so a real α
    # must strictly reduce it
    assert peaks[-1] < peaks[0] - 1e-9, peaks


@pytest.mark.parametrize("kind", ["zb-v", "stp", "stp-memeff"])
def test_offload_touches_only_chunk0(kind):
    """With chunk-0 m_a zeroed, nothing is offloadable: peak memory (all of
    it now chunk-1 resident) must be exactly independent of α."""
    p, m = 2, 6
    tables, pl = build(kind, p, m)
    m_a = np.array([0.0 if pl.chunk(vs) == 0 else 1.0
                    for vs in range(pl.n_vs)])
    t = StageTimes.uniform(pl.n_vs)
    t = StageTimes(t.t_f, t.t_b, t.t_w, t.t_ar, m_a, t.t_comm)
    base = simulate(tables, pl, t, m).peak_mem
    for a in (0.3, 0.7):
        np.testing.assert_array_equal(
            simulate(tables, pl, t, m, offload_alpha=a).peak_mem, base)


@pytest.mark.parametrize("kind", SCHEDULES)
def test_offload_overhead_is_exactly_per_chunk0_F(kind):
    """``offload_overhead`` charges each chunk-0 F and nothing else: every
    device's busy time grows by exactly (number of chunk-0 Fs it runs)·δ,
    and the makespan by at most the global sum."""
    p, m, delta = 2, 6, 0.25
    tables, pl = build(kind, p, m)
    t = StageTimes.uniform(pl.n_vs)
    base = simulate(tables, pl, t, m)
    off = simulate(tables, pl, t, m, offload_alpha=0.4,
                   offload_overhead=delta)
    n_f0 = np.zeros(pl.p)
    for d, tab in enumerate(tables):
        for ins in tab:
            if ins.f is not None and pl.chunk(ins.f[0]) == 0:
                n_f0[d] += 1
    np.testing.assert_allclose(off.busy - base.busy, n_f0 * delta,
                               atol=1e-9)
    assert base.total_time - 1e-9 <= off.total_time \
        <= base.total_time + n_f0.sum() * delta + 1e-9


def test_simulate_accepts_annotated_tables():
    tables, pl = build("stp-memeff", 2, 6)
    t = StageTimes.uniform(pl.n_vs)
    ann = annotate_offload(tables, pl)
    assert strip_offload(ann) == [list(tab) for tab in tables]
    base = simulate(tables, pl, t, 6, offload_alpha=0.4)
    got = simulate(ann, pl, t, 6, offload_alpha=0.4)
    assert got.total_time == base.total_time
    np.testing.assert_array_equal(got.peak_mem, base.peak_mem)
    with pytest.raises(ValueError, match="already carries"):
        annotate_offload(ann, pl)


# ---------------------------------------------------------------------------
# Offload-aware IR verification.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", SCHEDULES)
@pytest.mark.parametrize("p,m", [(2, 6), (4, 8)])
def test_verifier_accepts_annotated_tables_under_offload_bound(kind, p, m):
    tables, pl = build(kind, p, m)
    for alpha in (0.25, 0.5):
        peak = verify_tables(
            annotate_offload(tables, pl), pl, m,
            mem_bound=memory_bound(kind, p, m, offload_alpha=alpha),
            offload_alpha=alpha)
        # the offload-aware bound is strictly tighter than the naive one
        assert peak.max() <= memory_bound(kind, p, m) + 1e-9
        assert memory_bound(kind, p, m, offload_alpha=alpha) \
            < memory_bound(kind, p, m)


def _mutate(tables, pred, fn):
    """Apply ``fn`` (None = drop) to the first op matching ``pred``."""
    out, hit = [], False
    for tab in tables:
        ops = []
        for op in tab:
            if not hit and isinstance(op, OffloadOp) and pred(op):
                hit = True
                rep = fn(op)
                if rep is None:
                    continue
                ops.extend(rep)
                continue
            ops.append(op)
        out.append(ops)
    assert hit, "mutation target not found"
    return out


@pytest.mark.parametrize("mutation,msg", [
    # duplicate an OFFLOAD -> the α-slice is charged twice
    (lambda op: [op, op], "double-offload"),
    # drop an OFFLOAD -> its later FETCH has nothing to bring back
    (lambda op: None, "fetch-before-offload or double-fetch"),
])
def test_verifier_rejects_malformed_offload_lifetimes(mutation, msg):
    tables, pl = build("stp-memeff", 2, 6)
    bad = _mutate(annotate_offload(tables, pl),
                  lambda op: op.op == "OFFLOAD", mutation)
    with pytest.raises(ScheduleVerificationError, match=msg):
        verify_tables(bad, pl, 6, offload_alpha=0.4)


def test_verifier_rejects_missing_fetch_as_offload_leak():
    tables, pl = build("stp-memeff", 2, 6)
    bad = _mutate(annotate_offload(tables, pl),
                  lambda op: op.op == "FETCH", lambda op: None)
    with pytest.raises(ScheduleVerificationError, match="offload leak"):
        verify_tables(bad, pl, 6, offload_alpha=0.4)


# ---------------------------------------------------------------------------
# Executor differentials (subprocess: fixed device count).
# ---------------------------------------------------------------------------

def _run_sub(script: str):
    r = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        cwd=str(REPO), env={"PYTHONPATH": str(REPO / "src"),
                            "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"},
        timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


OFFLOAD_STEP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_config
from repro.core.schedule import build
from repro.models import model as M
from repro.pipeline.reference import reference_grads
from repro.pipeline.spmd import build_pipeline_step, stack_stage_params

p, m, b, s = {p}, {m}, 2, 16
tables, pl = build("{kind}", p, m)
cfg = get_config("qwen3-4b").reduced(n_layers=pl.n_vs, d_model=64,
                                     n_heads=4, vocab=128)
key = jax.random.PRNGKey(0)
params = M.init_params(key, cfg)
ks = jax.random.split(key, m)
batches = [{{"tokens": jax.random.randint(k, (b, s), 0, cfg.vocab),
            "labels": jax.random.randint(k, (b, s), 0, cfg.vocab)}}
           for k in ks]
mesh = Mesh(np.array(jax.devices()).reshape(p, 1), ("stage", "model"))
c0, c1, lvs = stack_stage_params(params, cfg, p, kind=pl.kind)
trees = (c0, c1, params["embed"], params["head"])
tokens = jnp.stack([bb["tokens"] for bb in batches])
labels = jnp.stack([bb["labels"] for bb in batches])
loss_ref, _ = reference_grads(params, batches, cfg)

def run(fuse, alpha, braid=False):
    step = build_pipeline_step(cfg, tables, pl, mesh, m, (b, s), trees,
                               fuse_slots=fuse, braid_tp=braid,
                               offload_alpha=alpha)
    with mesh:
        out = step(c0, c1, params["embed"], params["head"], tokens, labels)
    return jax.device_get(out)

def maxdiff(a, bb):
    return max(float(np.max(np.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(bb)))

for fuse in {lowerings}:
    base = run(fuse, 0.0)
    off = run(fuse, 0.4)
    # loss (leaf 0) against the jax.grad oracle, offloaded vs naive exact
    assert np.allclose(jax.tree.leaves(off)[0], loss_ref, rtol=1e-5)
    d = maxdiff(base, off)
    assert d < 1e-5, (fuse, d)
    print(f"fuse={{fuse}} maxdiff={{d:.2e}}")
if {braid}:
    d = maxdiff(run(True, 0.0, braid=True), run(True, 0.4, braid=True))
    assert d < 1e-5, ("braid", d)
    print(f"braid maxdiff={{d:.2e}}")
print("OK")
"""


def _offload_case(kind, p=2, m=6, ndev=2, lowerings=(True, False),
                  braid=False):
    out = _run_sub(OFFLOAD_STEP_SCRIPT.format(
        ndev=ndev, p=p, m=m, kind=kind,
        lowerings=tuple(lowerings), braid="True" if braid else "False"))
    assert "OK" in out


def test_spmd_offload_matches_naive_stp_memeff():
    """Fast-tier pin of the acceptance contract on the paper's enhanced
    schedule: fused lowering, α=0.4 vs α=0 (<1e-5; bitwise in practice)."""
    _offload_case("stp-memeff", lowerings=(True,))


@pytest.mark.slow
@pytest.mark.parametrize("kind", SCHEDULES)
def test_spmd_offload_matches_naive_all_kinds(kind):
    """Slow-tier matrix: every schedule kind, both lowerings (+ the braided
    executor for the braidable kinds)."""
    _offload_case(kind, braid=kind in ("stp", "stp-memeff"))


OFFLOAD_TRAIN_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp, numpy as np
from repro.api import make_runner
from repro.configs import get_config
from repro.data import DataConfig, make_batches
from repro.models import model as M
from repro.optim import OptConfig

m = 4
cfg = get_config("qwen3-4b").reduced(n_layers=4, d_model=64, n_heads=4,
                                     vocab=128)
oc = OptConfig(lr=1e-3, warmup_steps=2, total_steps=4)
dc = DataConfig(seq_len=32, global_batch=2 * m, microbatches=m)
batches = [{k: jnp.asarray(v) for k, v in raw.items()}
           for raw in make_batches(cfg, dc, 2)]
params = M.init_params(jax.random.PRNGKey(0), cfg)

def train(alpha):
    r = make_runner("spmd", cfg, oc, dc, schedule="stp-memeff", pp=2,
                    offload_alpha=alpha)
    if alpha > 0:
        assert r.act_stats["host_act_bytes"] > 0
        assert "off=0.4" in r.describe
    state = r.init_state(params)
    out = []
    for bt in batches:
        state, mx = r.step(state, bt)
        out.append((float(mx["loss"]), float(mx["gnorm"])))
    return out, jax.device_get(state.params)

base, p0 = train(0.0)
off, p1 = train(0.4)
assert base == off, (base, off)      # losses/gnorms bitwise over 2 steps
d = max(float(np.max(np.abs(a - b)))
        for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)))
assert d < 1e-5, d
print("OK", base[-1][0], d)
"""


@pytest.mark.slow
def test_spmd_offload_train_step_matches_naive():
    """The fused train step (in-mesh AdamW) with α=0.4 reproduces the α=0
    losses, grad norms and updated params over two steps."""
    out = _run_sub(OFFLOAD_TRAIN_SCRIPT)
    assert "OK" in out
