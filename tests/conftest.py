"""Suite-wide hygiene: the full suite jits hundreds of shape variants in
one process; clearing jax's compile caches between modules keeps the
1-core/35GB container from exhausting memory (LLVM OOM) late in the run."""
import gc

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()
    gc.collect()
