"""TrainState/Runner API: layout round-trips, runtime-portable
checkpoints, and equivalence of the fused in-mesh AdamW step with the old
grads_fn + host ``adamw_update`` path."""
import inspect
import subprocess
import sys
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (Layout, PjitRunner, ReferenceRunner, SpmdRunner,
                       TrainState, decay_mask, load_state, make_runner,
                       save_state)
from repro.configs import get_config
from repro.data import DataConfig, make_batches
from repro.models import model as M
from repro.optim import OptConfig, adamw_init

REPO = Path(__file__).resolve().parent.parent


def _cfg(n_layers=4):
    return get_config("qwen3-4b").reduced(n_layers=n_layers, d_model=64,
                                          n_heads=4, vocab=128)


def _nonzero_opt(params):
    """AdamW state with distinct, nonzero moments so conversion bugs show."""
    opt = adamw_init(params)
    leaves, treedef = jax.tree.flatten(params)
    mu = jax.tree.unflatten(treedef, [0.5 * x + i for i, x in
                                      enumerate(leaves)])
    nu = jax.tree.unflatten(treedef, [x * x + 2.0 * i for i, x in
                                      enumerate(leaves)])
    return {"mu": mu, "nu": nu, "step": jnp.asarray(7, jnp.int32)}


def _tree_eq(a, b):
    fa, ta = jax.tree.flatten(a)
    fb, tb = jax.tree.flatten(b)
    assert ta == tb
    return max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
               for x, y in zip(fa, fb))


@pytest.mark.parametrize("layout", [
    Layout("period", 4, period=1),
    Layout("stage", 4, p=2, lvs=2, placement="flat"),
    Layout("stage", 4, p=2, lvs=1, placement="parallel"),
    Layout("stage", 4, p=2, lvs=1, placement="vshape"),
], ids=["period", "flat", "parallel", "vshape"])
def test_from_to_canonical_roundtrip(layout):
    cfg = _cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = _nonzero_opt(params)
    st = TrainState.from_canonical(params, layout, opt=opt)
    p2, o2 = st.to_canonical()
    assert _tree_eq(p2, params) == 0.0
    assert _tree_eq(o2["mu"], opt["mu"]) == 0.0
    assert _tree_eq(o2["nu"], opt["nu"]) == 0.0
    assert int(o2["step"]) == 7 and int(st.step) == 7


def test_decay_mask_tracks_canonical_rank():
    """Stacking dims must not promote biases/norm gains into decayed
    matrices: the stacked mask equals the canonical mask restacked."""
    cfg = _cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    can = decay_mask(params, Layout("canonical", 4))
    for layout in (Layout("period", 4, period=1),
                   Layout("stage", 4, p=2, lvs=1, placement="vshape")):
        st = TrainState.from_canonical(params, layout)
        mask = decay_mask(st.params, layout)
        # layer 0 lives at blocks[0] / stacked position (0, 0)
        for (path, want) in jax.tree_util.tree_flatten_with_path(
                can["blocks"][0])[0]:
            got = mask["blocks"][0] if layout.kind == "period" \
                else mask["c0"]
            for k in path:
                got = got[k.key] if hasattr(k, "key") else got[k.idx]
            assert got == want, (layout.kind, path, got, want)
        assert decay_mask(st.params, layout)["embed"] == can["embed"]
        assert decay_mask(st.params, layout)["head"] == can["head"]


def test_checkpoint_roundtrip_across_runtimes(tmp_path):
    """A pjit-runner checkpoint resumes into any layout with step and AdamW
    moments intact (regression: the old pjit path re-initialized moments
    after load_checkpoint)."""
    cfg = _cfg(n_layers=2)
    oc = OptConfig(lr=3e-3, warmup_steps=2, total_steps=10)
    dc = DataConfig(seq_len=16, global_batch=4, microbatches=2)
    runner = PjitRunner(cfg, oc)
    state = runner.init_state(M.init_params(jax.random.PRNGKey(0), cfg))
    for raw in make_batches(cfg, dc, 2):
        state, _ = runner.step(state, {k: jnp.asarray(v)
                                       for k, v in raw.items()})
    save_state(tmp_path, state, extra={"arch": cfg.name})

    for layout in (runner.layout, Layout("canonical", 2),
                   Layout("stage", 2, p=2, lvs=1, placement="flat")):
        st2, step, extra = load_state(tmp_path, cfg, layout)
        assert step == 2 and int(st2.step) == 2
        assert extra["arch"] == cfg.name
        _, o2 = st2.to_canonical()
        assert max(float(np.max(np.abs(np.asarray(x))))
                   for x in jax.tree.leaves(o2["mu"])) > 0
    # ...and the reference runner continues training from it
    ref = ReferenceRunner(cfg, oc, "gpipe", 2, dc.microbatches)
    st3, step3, _ = load_state(tmp_path, cfg, ref.layout)
    raw = next(iter(make_batches(cfg, dc, 1)))
    st3, met = ref.step(st3, {k: jnp.asarray(v) for k, v in raw.items()})
    assert int(st3.step) == 3 and np.isfinite(float(met["loss"]))


def test_spmd_step_has_no_host_restack():
    """Acceptance guard: the per-step path must not re-stack params
    host-side — stacking happens once in init_state."""
    src = inspect.getsource(SpmdRunner.step)
    assert "stack_stage_params" not in src
    assert "from_canonical" not in src


EQUIV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_config
from repro.data import DataConfig, make_batches, microbatches
from repro.launch.runner import SpmdRunner
from repro.launch.steps import make_pipeline_grads_fn
from repro.models import model as M
from repro.optim import OptConfig, adamw_init, adamw_update

cfg = get_config("qwen3-4b").reduced(n_layers=4, d_model=64, n_heads=4,
                                     vocab=128)
oc = OptConfig(lr=3e-3, warmup_steps=2, total_steps=10)
dc = DataConfig(seq_len=16, global_batch=8, microbatches=4)
m = 4
batches = [{k: jnp.asarray(v) for k, v in raw.items()}
           for raw in make_batches(cfg, dc, 3)]

# old path: per-step host re-stacking grads_fn + host AdamW on canonical
params = M.init_params(jax.random.PRNGKey(0), cfg)
opt = adamw_init(params)
mesh = Mesh(np.array(jax.devices()).reshape(2, 1), ("stage", "model"))
grads_fn, pl = make_pipeline_grads_fn(cfg, "stp", 2, m, (2, 16), mesh,
                                      params)
for b in batches:
    mbs = microbatches(b, m)
    tokens = jnp.stack([x["tokens"] for x in mbs])
    labels = jnp.stack([x["labels"] for x in mbs])
    loss, grads = grads_fn(params, tokens, labels)
    params, opt, gn = adamw_update(params, grads, opt, oc)

# new path: fused in-mesh AdamW, mesh-resident state
runner = SpmdRunner(cfg, oc, "stp", 2, m, (2, 16))
state = runner.init_state(M.init_params(jax.random.PRNGKey(0), cfg))
for b in batches:
    state, metrics = runner.step(state, b)
p2, o2 = state.to_canonical()

def rel(g, g_ref):
    fp, tp_ = jax.tree.flatten(g)
    fr, tr = jax.tree.flatten(g_ref)
    assert tr == tp_
    return max(float(np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9))
               for a, b in zip(fp, fr))

errs = (rel(p2, params), rel(o2["mu"], opt["mu"]), rel(o2["nu"], opt["nu"]))
assert all(e < 1e-5 for e in errs), errs
assert int(o2["step"]) == int(opt["step"]) == 3
assert np.allclose(float(metrics["loss"]), float(loss), rtol=1e-5)
print("OK", errs)
"""


@pytest.mark.slow
def test_spmd_runner_matches_host_adamw():
    """SpmdRunner.step (AdamW under shard_map) == old grads_fn + host
    adamw_update to within 1e-5 over 3 steps, on a real 2-device mesh.
    Slow tier: the subprocess compiles two full shard_map train programs
    (minutes on 1 CPU core)."""
    r = subprocess.run(
        [sys.executable, "-c", EQUIV_SCRIPT], capture_output=True,
        text=True, cwd=str(REPO),
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "OK" in r.stdout
