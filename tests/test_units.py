"""Fine-grained unit F/B/W correctness: every unit's hand-split backward
(B propagates activations + joint core grads, W consumes the weight tape)
must equal jax.grad of its own forward — per unit kind, plus hypothesis
property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                   # hypothesis optional: only the
    class _AnyStrategy:               # property tests skip without it
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        def deco(f):
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = f.__name__
            return skipped
        return deco

from repro.configs import get_config
from repro.core import autograd as ag
from repro.models import model as M, ssm, units
from repro.models.config import LayerSpec, ModelConfig
from repro.tp.context import TPContext

TP0 = TPContext()
KEY = jax.random.PRNGKey(7)


def check_layer_fbw(cfg, spec, key, b=2, s=16, atol=2e-4):
    """layer_bwd_act + layer_bwd_weight == jax.grad(layer loss)."""
    params = M.init_layer(key, spec, cfg, 0.02)
    x = jax.random.normal(key, (b, s, cfg.d_model))
    rope = M._rope_for(cfg, s)

    def loss(p, x):
        y, _ = M.layer_fwd(p, TP0, x, rope, spec, cfg)
        return (y.astype(jnp.float32) ** 2).sum()

    (g_ref, gx_ref) = jax.grad(loss, argnums=(0, 1))(params, x)

    y, ctx = M.layer_fwd(params, TP0, x, rope, spec, cfg)
    gy = (2 * y).astype(y.dtype)
    gx, wtape, joints = M.layer_bwd_act(params, TP0, ctx, gy, spec, cfg)
    gw = M.layer_bwd_weight(wtape, spec)

    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref),
                               atol=atol, rtol=1e-3)
    merged = {}

    def merge(dst, src):
        for k, v in src.items():
            if isinstance(v, dict):
                merge(dst.setdefault(k, {}), v)
            else:
                dst[k] = dst.get(k, 0) + v

    merge(merged, joints)
    merge(merged, gw)
    flat_ref, td_ref = jax.tree_util.tree_flatten(g_ref)
    flat, td = jax.tree_util.tree_flatten(merged)
    assert td == td_ref, (td, td_ref)
    for a, r in zip(flat, flat_ref):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(r, np.float32),
                                   atol=atol, rtol=2e-3)


@pytest.mark.parametrize("mixer,mlp,qk,win", [
    ("attn", "gated", False, None),
    ("attn", "plain", False, None),
    ("attn", "gated", True, None),       # qk_norm (qwen3)
    ("attn", "gated", False, 8),         # sliding window (gemma3)
    ("attn", "moe", False, None),        # MoE layer (olmoe)
    ("mamba", "gated", False, None),     # jamba mamba layer
    ("mlstm", "none", False, None),      # xlstm
    ("slstm", "none", False, None),
])
def test_layer_fbw_matches_grad(mixer, mlp, qk, win):
    from repro.models.config import MoEConfig
    cfg = ModelConfig(
        name="t", family="dense", d_model=64, n_heads=4, kv_heads=2,
        d_ff=128, vocab=97,
        layers=(LayerSpec(mixer=mixer, mlp=mlp, qk_norm=qk, window=win),),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=64) if mlp == "moe"
        else None,
        use_rope=(mixer == "attn"))
    check_layer_fbw(cfg, cfg.layers[0], KEY)


def test_head_fbw_matches_grad():
    cfg = get_config("qwen3-4b").reduced(n_layers=1, d_model=64, n_heads=4,
                                         vocab=128)
    params = M.init_params(KEY, cfg)["head"]
    x = jax.random.normal(KEY, (2, 8, 64))
    labels = jax.random.randint(KEY, (2, 8), 0, 128)

    def loss(p, x):
        l, _ = M.head_fwd(p, TP0, x, labels, cfg)
        return l

    g_ref, gx_ref = jax.grad(loss, argnums=(0, 1))(params, x)
    l, ctx = M.head_fwd(params, TP0, x, labels, cfg)
    gx, wtape, joint = M.head_bwd_act(params, TP0, ctx, jnp.float32(1.0),
                                      cfg)
    gw = M.head_bwd_weight(wtape)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref),
                               atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gw["w_lm"]),
                               np.asarray(g_ref["w_lm"]), atol=1e-5,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(joint["ln_f"]["g"]),
                               np.asarray(g_ref["ln_f"]["g"]), atol=1e-5,
                               rtol=1e-3)


def test_residual_fusion_tp_equivalence():
    """Eq. (1)/(2): the fused-residual unit under a real shard_map TP group
    equals the unfused single-device computation (fwd and bwd)."""
    import subprocess, sys, textwrap
    from pathlib import Path
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.models import model as M, units
        from repro.models.config import LayerSpec, ModelConfig
        from repro.tp.context import TPContext

        cfg = ModelConfig(name="t", family="dense", d_model=64, n_heads=4,
                          kv_heads=4, d_ff=128, vocab=97,
                          layers=(LayerSpec(),))
        spec = cfg.layers[0]
        key = jax.random.PRNGKey(0)
        params = M.init_layer(key, spec, cfg, 0.02)
        x = jax.random.normal(key, (2, 16, 64))
        rope = M._rope_for(cfg, 16)
        y_ref, _ = M.layer_fwd(params, TPContext(), x, rope, spec, cfg)

        mesh = Mesh(np.array(jax.devices()), ("model",))
        tp = TPContext(axis="model", size=4)
        pspec = {"ln1": {"g": P()}, "ln2": {"g": P()},
                 "mixer": {"wq": P(None, "model"), "wk": P(None, "model"),
                           "wv": P(None, "model"), "wo": P("model", None)},
                 "mlp": {"wg": P(None, "model"), "wu": P(None, "model"),
                         "wd": P("model", None)}}

        def f(p, x):
            y, ctx = M.layer_fwd(p, tp, x, rope, spec, cfg)
            gx, wt, j = M.layer_bwd_act(p, tp, ctx, 2 * y, spec, cfg)
            return y, gx

        y, gx = shard_map(f, mesh=mesh, in_specs=(pspec, P()),
                          out_specs=(P(), P()), check_rep=False)(params, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=2e-5, rtol=1e-4)
        # bwd vs autodiff
        gx_ref = jax.grad(lambda xx: (M.layer_fwd(params, TPContext(), xx,
                          rope, spec, cfg)[0] ** 2).sum())(x)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref),
                                   atol=2e-4, rtol=1e-3)
        print("OK")
    """)
    repo = Path(__file__).resolve().parent.parent
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env={"PYTHONPATH": str(repo / "src"),
                                       "PATH": "/usr/bin:/bin"},
                       timeout=600)
    assert r.returncode == 0 and "OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# Hypothesis property tests.
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(b=st.integers(1, 3), s=st.integers(1, 33), causal=st.booleans(),
       hq=st.sampled_from([1, 2, 4]), g=st.sampled_from([1, 2]))
def test_flash_attention_matches_reference_property(b, s, causal, hq, g):
    from repro.models.attention_core import (flash_attention,
                                             reference_attention)
    hkv = max(1, hq // g)
    hq = hkv * g
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(b * 100 + s), 3)
    q = jax.random.normal(k1, (b, hq, s, 8))
    k = jax.random.normal(k2, (b, hkv, s, 8))
    v = jax.random.normal(k3, (b, hkv, s, 8))
    o = flash_attention(q, k, v, causal, None)
    r = reference_attention(q, k, v, causal, None)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5,
                               rtol=1e-4)


@settings(max_examples=8, deadline=None)
@given(s=st.integers(2, 40), k=st.integers(1, 4), e=st.sampled_from([4, 8]),
       cap=st.floats(0.5, 2.0))
def test_moe_routing_invariants(s, k, e, cap):
    """Router invariants: capacity positions are unique per expert, kept
    tokens never exceed capacity, gates are a valid sub-distribution."""
    from repro.models.config import MoEConfig
    from repro.models.units import _gates_core, _route, moe_capacity
    k = min(k, e)
    moe = MoEConfig(num_experts=e, top_k=k, d_ff=8, capacity_factor=cap)
    C = moe_capacity(s, moe)
    logits = jax.random.normal(jax.random.PRNGKey(s * 7 + k), (1, s, e))
    idx, pos, keep = _route(logits, k, C)
    idx, pos, keep = (np.asarray(idx[0]), np.asarray(pos[0]),
                      np.asarray(keep[0]))
    # no duplicate (expert, slot) among kept tokens
    slots = [(int(idx[i, j]), int(pos[i, j]))
             for i in range(s) for j in range(k) if keep[i, j] > 0]
    assert len(slots) == len(set(slots))
    assert pos.max(initial=0) < C
    gates = np.asarray(_gates_core(logits, jnp.asarray(idx)[None]))[0]
    assert np.all(gates >= 0) and np.all(gates.sum(-1) <= 1 + 1e-5)


@settings(max_examples=8, deadline=None)
@given(s=st.integers(1, 50), chunk=st.sampled_from([4, 16, 64]))
def test_chunked_scan_equals_plain_scan(s, chunk):
    def step(c, x):
        return c * 0.9 + x, c + x

    xs = jax.random.normal(jax.random.PRNGKey(s), (s, 8))
    c1, y1 = jax.lax.scan(step, jnp.zeros(8), xs)
    c2, y2 = ssm.chunked_scan(step, jnp.zeros(8), xs, chunk=chunk)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(1, 64), mult=st.sampled_from([8, 128]))
def test_pad_roundtrip(n, mult):
    from repro.models.attention_core import _pad_to
    x = jnp.ones((2, n, 4))
    p = _pad_to(x, mult, 1)
    assert p.shape[1] % mult == 0
    np.testing.assert_array_equal(np.asarray(p[:, :n]), np.asarray(x))


@settings(max_examples=8, deadline=None)
@given(steps=st.integers(1, 30))
def test_lr_schedule_monotone_warmup(steps):
    from repro.optim.adamw import OptConfig, lr_at
    oc = OptConfig(warmup_steps=10, total_steps=50)
    lrs = [float(lr_at(oc, i)) for i in range(steps)]
    warm = lrs[: min(steps, 10)]
    assert all(b >= a - 1e-9 for a, b in zip(warm, warm[1:]))
    assert all(l <= oc.lr + 1e-9 for l in lrs)


def test_tiny_preserves_moe_routing():
    """ModelConfig.tiny() shrinks widths but must NOT touch the routing
    problem: num_experts / top_k survive so capacity buckets, drops, and
    expert-parallel divisibility match the full model (reduced() caps
    experts at 4, which breaks ep > 4 and changes drop patterns)."""
    from repro.configs import get_config
    cfg = get_config("olmoe-1b-7b")
    t = cfg.tiny()
    assert t.moe is not None
    assert t.moe.num_experts == cfg.moe.num_experts
    assert t.moe.top_k == cfg.moe.top_k
    assert t.d_model < cfg.d_model and t.n_layers <= 2
    assert t.frontend == cfg.frontend
    # dense configs: no phantom moe appears
    assert get_config("qwen3-4b").tiny().moe is None


def test_moe_fbw_tp2_matches_grad():
    """units.moe_fwd/moe_bwd_act under a real 2-way TP shard_map group
    (expert weights sharded on their f dim, psum over 'model') must equal
    the single-device jax.grad oracle."""
    import subprocess, sys, textwrap
    from pathlib import Path
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import model as M, units
        from repro.pipeline.spmd import tp_specs
        from repro.tp.context import TPContext

        cfg = get_config("olmoe-1b-7b").reduced(n_layers=1, d_model=64,
                                                n_heads=4, vocab=128)
        spec = cfg.layers[0]
        key = jax.random.PRNGKey(3)
        params = M.init_layer(key, spec, cfg, 0.02)["mlp"]
        x = jax.random.normal(key, (2, 16, 64))
        res = jax.random.normal(jax.random.PRNGKey(4), (2, 16, 64))

        def loss(p, xx):
            y, _ = units.moe_fwd(p, TPContext(), xx, res, spec, cfg)
            return (y.astype(jnp.float32) ** 2).sum()

        gx_ref = jax.grad(loss, argnums=1)(params, x)
        y_ref, _ = units.moe_fwd(params, TPContext(), x, res, spec, cfg)

        mesh = Mesh(np.array(jax.devices()), ("model",))
        tp = TPContext(axis="model", size=2)
        pspec = tp_specs(params, "model", None)

        def f(p, xx):
            y, ctx = units.moe_fwd(p, tp, xx, res, spec, cfg)
            gx, gres, wt, j = units.moe_bwd_act(p, tp, ctx, 2 * y, spec,
                                                cfg)
            return y, gx

        y, gx = shard_map(f, mesh=mesh, in_specs=(pspec, P()),
                          out_specs=(P(), P()), check_rep=False)(params, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=2e-5, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref),
                                   atol=2e-4, rtol=1e-3)
        print("OK")
    """)
    repo = Path(__file__).resolve().parent.parent
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env={"PYTHONPATH": str(repo / "src"),
                                       "PATH": "/usr/bin:/bin"},
                       timeout=600)
    assert r.returncode == 0 and "OK" in r.stdout, r.stdout + r.stderr
