"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles (interpret mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import (flash_attention, matmul_accumulate, rmsnorm)
from repro.kernels.ref import (reference_matmul_psum_step,
                               reference_rmsnorm)
from repro.models.attention_core import (flash_attention as model_flash,
                                         reference_attention)

KEY = jax.random.PRNGKey(42)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,S,T,D", [
    (1, 2, 2, 16, 16, 8),
    (2, 4, 2, 64, 64, 32),        # GQA group 2
    (1, 8, 1, 40, 40, 16),        # MQA, ragged seq
    (2, 4, 4, 128, 128, 64),      # MXU-aligned
    (1, 2, 2, 257, 257, 16),      # pad both blocks
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(dtype, B, Hq, Hkv, S, T, D, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, T, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, T, D)).astype(dtype)
    o = flash_attention(q, k, v, causal=causal)
    r = reference_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), **tol(dtype))


@pytest.mark.parametrize("window", [8, 64, 1024])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 96, 16))
    k = jax.random.normal(ks[1], (1, 2, 96, 16))
    v = jax.random.normal(ks[2], (1, 2, 96, 16))
    o = flash_attention(q, k, v, causal=True, window=window)
    r = reference_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5,
                               rtol=2e-5)


def test_flash_attention_decode_offset():
    """q_offset: one-token decode against a longer KV (serve_step shape)."""
    ks = jax.random.split(KEY, 3)
    T = 64
    q = jax.random.normal(ks[0], (2, 4, 1, 16))
    k = jax.random.normal(ks[1], (2, 4, T, 16))
    v = jax.random.normal(ks[2], (2, 4, T, 16))
    o = flash_attention(q, k, v, causal=True, q_offset=T - 1)
    r = reference_attention(q, k, v, causal=True, q_offset=T - 1)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5,
                               rtol=2e-5)


def test_kernel_matches_model_attention_path():
    """The model-path chunked flash attention (custom_vjp) and the Pallas
    kernel agree — the kernel can be swapped into the Attn unit."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 8, 64, 32))
    k = jax.random.normal(ks[1], (2, 2, 64, 32))
    v = jax.random.normal(ks[2], (2, 2, 64, 32))
    o_model = model_flash(q, k, v, True, None)
    o_kernel = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o_model), np.asarray(o_kernel),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(7, 64), (4, 33, 129), (2, 8, 16, 256)])
def test_rmsnorm_sweep(dtype, shape):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], shape).astype(dtype)
    g = (jax.random.normal(ks[1], shape[-1:]) + 1.0).astype(dtype)
    o = rmsnorm(x, g)
    r = reference_rmsnorm(x, g)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), **tol(dtype))


def test_rmsnorm_row_invariance():
    """Property: rmsnorm is scale-invariant per row (g fixed)."""
    x = jax.random.normal(KEY, (5, 64))
    g = jnp.ones((64,))
    o1 = rmsnorm(x, g)
    o2 = rmsnorm(x * 7.3, g)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n", [
    (8, 16, 8),
    (128, 128, 128),        # one block exactly
    (256, 384, 128),        # multi-block grid
    (7, 33, 65),            # ragged: every dim padded
    (130, 257, 129),        # pad past one block
])
def test_matmul_accumulate_sweep(dtype, m, k, n):
    """The collective-matmul ring hop (matmul + accumulate fused in the
    epilogue) against the fp32 oracle — fp32 inputs must be bitwise."""
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (m, k)).astype(dtype)
    w = jax.random.normal(ks[1], (k, n)).astype(dtype)
    acc = jax.random.normal(ks[2], (m, n), jnp.float32)
    o = matmul_accumulate(x, w, acc)
    r = reference_matmul_psum_step(x, w, acc)
    assert o.dtype == jnp.float32
    if dtype == jnp.float32 and k <= 128:
        # single K step: same fp32 dot + one add as the oracle
        np.testing.assert_array_equal(np.asarray(o), np.asarray(r))
    else:
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   atol=1e-4, rtol=1e-5)
