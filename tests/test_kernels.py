"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles (interpret mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import (flash_attention, matmul_accumulate, rmsnorm)
from repro.kernels.ref import (reference_matmul_psum_step,
                               reference_rmsnorm)
from repro.models.attention_core import (flash_attention as model_flash,
                                         reference_attention)

KEY = jax.random.PRNGKey(42)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,S,T,D", [
    (1, 2, 2, 16, 16, 8),
    (2, 4, 2, 64, 64, 32),        # GQA group 2
    (1, 8, 1, 40, 40, 16),        # MQA, ragged seq
    (2, 4, 4, 128, 128, 64),      # MXU-aligned
    (1, 2, 2, 257, 257, 16),      # pad both blocks
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(dtype, B, Hq, Hkv, S, T, D, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, T, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, T, D)).astype(dtype)
    o = flash_attention(q, k, v, causal=causal)
    r = reference_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), **tol(dtype))


@pytest.mark.parametrize("window", [8, 64, 1024])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 96, 16))
    k = jax.random.normal(ks[1], (1, 2, 96, 16))
    v = jax.random.normal(ks[2], (1, 2, 96, 16))
    o = flash_attention(q, k, v, causal=True, window=window)
    r = reference_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5,
                               rtol=2e-5)


def test_flash_attention_decode_offset():
    """q_offset: one-token decode against a longer KV (serve_step shape)."""
    ks = jax.random.split(KEY, 3)
    T = 64
    q = jax.random.normal(ks[0], (2, 4, 1, 16))
    k = jax.random.normal(ks[1], (2, 4, T, 16))
    v = jax.random.normal(ks[2], (2, 4, T, 16))
    o = flash_attention(q, k, v, causal=True, q_offset=T - 1)
    r = reference_attention(q, k, v, causal=True, q_offset=T - 1)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5,
                               rtol=2e-5)


def test_kernel_matches_model_attention_path():
    """The model-path chunked flash attention (custom_vjp) and the Pallas
    kernel agree — the kernel can be swapped into the Attn unit."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 8, 64, 32))
    k = jax.random.normal(ks[1], (2, 2, 64, 32))
    v = jax.random.normal(ks[2], (2, 2, 64, 32))
    o_model = model_flash(q, k, v, True, None)
    o_kernel = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o_model), np.asarray(o_kernel),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(7, 64), (4, 33, 129), (2, 8, 16, 256)])
def test_rmsnorm_sweep(dtype, shape):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], shape).astype(dtype)
    g = (jax.random.normal(ks[1], shape[-1:]) + 1.0).astype(dtype)
    o = rmsnorm(x, g)
    r = reference_rmsnorm(x, g)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), **tol(dtype))


def test_rmsnorm_row_invariance():
    """Property: rmsnorm is scale-invariant per row (g fixed)."""
    x = jax.random.normal(KEY, (5, 64))
    g = jnp.ones((64,))
    o1 = rmsnorm(x, g)
    o2 = rmsnorm(x * 7.3, g)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n", [
    (8, 16, 8),
    (128, 128, 128),        # one block exactly
    (256, 384, 128),        # multi-block grid
    (7, 33, 65),            # ragged: every dim padded
    (130, 257, 129),        # pad past one block
])
def test_matmul_accumulate_sweep(dtype, m, k, n):
    """The collective-matmul ring hop (matmul + accumulate fused in the
    epilogue) against the fp32 oracle — fp32 inputs must be bitwise."""
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (m, k)).astype(dtype)
    w = jax.random.normal(ks[1], (k, n)).astype(dtype)
    acc = jax.random.normal(ks[2], (m, n), jnp.float32)
    o = matmul_accumulate(x, w, acc)
    r = reference_matmul_psum_step(x, w, acc)
    assert o.dtype == jnp.float32
    if dtype == jnp.float32 and k <= 128:
        # single K step: same fp32 dot + one add as the oracle
        np.testing.assert_array_equal(np.asarray(o), np.asarray(r))
    else:
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   atol=1e-4, rtol=1e-5)


# ---------------------------------------------------------------------------
# Ragged-dispatch MoE routing kernel.
# ---------------------------------------------------------------------------

def _routing_case(b=2, s=16, E=4, k=2, C=5, seed=0):
    """Routing decisions with C small enough to force capacity drops."""
    from repro.models.units import _route
    logits = jax.random.normal(jax.random.PRNGKey(seed), (b, s, E))
    idx, pos, keep = _route(logits, k, C)
    assert float((1 - keep).sum()) > 0, "case must exercise overflow drops"
    return idx, pos, keep


@pytest.mark.parametrize("d", [96, 128, 200])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ragged_dispatch_matches_dense_oracle(d, dtype):
    """Kernel gather == dense scatter-add oracle == units._dispatch,
    bitwise, including which tokens drop on capacity overflow."""
    from repro.kernels.ops import ragged_dispatch
    from repro.kernels.ref import reference_ragged_dispatch
    from repro.models.units import _dispatch
    b, s, E, C = 2, 16, 4, 5
    idx, pos, keep = _routing_case(b=b, s=s, E=E, C=C)
    x = jax.random.normal(KEY, (b, s, d)).astype(dtype)
    kern = ragged_dispatch(x, idx, pos, keep, E, C)
    orc = jax.vmap(lambda xr, ir, pr, kr: reference_ragged_dispatch(
        xr, ir, pr, kr, E, C))(x, idx, pos, keep)
    dense = _dispatch(x, idx, pos, keep, E, C)
    assert kern.shape == (b, E, C, d) and kern.dtype == dtype
    np.testing.assert_array_equal(np.asarray(kern, np.float32),
                                  np.asarray(orc, np.float32))
    np.testing.assert_array_equal(np.asarray(kern, np.float32),
                                  np.asarray(dense, np.float32))


def test_ragged_dispatch_slot_map_deterministic_drops():
    """The slot map is a function of the routing decisions alone: rebuilt
    maps are identical, kept slots each have exactly one owner, and dropped
    (token, k) slots never appear."""
    from repro.kernels.ragged_dispatch import build_slot_map
    E, C = 4, 5
    idx, pos, keep = _routing_case(E=E, C=C)
    i0, p0, k0 = idx[0], pos[0], keep[0]
    s1 = np.asarray(build_slot_map(i0, p0, k0, E, C))
    s2 = np.asarray(build_slot_map(i0, p0, k0, E, C))
    np.testing.assert_array_equal(s1, s2)
    occupied = s1[s1 >= 0]
    assert len(occupied) == int(np.asarray(k0).sum())
    assert len(set(occupied.tolist())) <= i0.shape[0]  # owners are tokens
    # every kept (token, slot) pair is present at its routed position
    kn = np.asarray(k0) > 0
    for t in range(i0.shape[0]):
        for j in range(i0.shape[1]):
            slot = int(i0[t, j]) * C + int(p0[t, j])
            if kn[t, j]:
                assert s1[slot] == t
    # dropped pairs own nothing: total occupancy == total keeps (above)


def test_moe_fwd_ragged_dispatch_flag():
    """units.set_ragged_dispatch routes moe_fwd through the kernel without
    changing a single bit of the output."""
    from repro.configs import get_config
    from repro.models import model as M, units
    from repro.tp.context import TPContext
    cfg = get_config("olmoe-1b-7b").reduced(n_layers=1, d_model=64,
                                            n_heads=4, vocab=128)
    spec = cfg.layers[0]
    params = M.init_layer(KEY, spec, cfg, 0.02)["mlp"]
    x = jax.random.normal(KEY, (2, 16, 64))
    res = jax.random.normal(jax.random.PRNGKey(9), (2, 16, 64))
    tp0 = TPContext()
    y0, _ = units.moe_fwd(params, tp0, x, res, spec, cfg)
    units.set_ragged_dispatch(True)
    try:
        y1, _ = units.moe_fwd(params, tp0, x, res, spec, cfg)
    finally:
        units.set_ragged_dispatch(False)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
