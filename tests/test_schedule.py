"""Schedule + simulator behaviour: validity, Table 1 closed forms, and the
paper's qualitative experimental claims (§5)."""
import numpy as np
import pytest

from repro.core import schedule as sch
from repro.core.simulator import (ScheduleVerificationError, StageTimes,
                                  simulate, verify_tables)
from repro.core.theory import THEORY, UnitTimes, ideal_time


def times_for(kind: str, p: int, u: UnitTimes, t_comm: float = 0.0):
    if kind in ("gpipe", "1f1b"):   # v = 1: one chunk carries both halves
        return StageTimes.uniform(p, t_f=2 * u.t_f, t_b=2 * u.t_b,
                                  t_w=2 * u.t_w, t_ar=2 * u.t_ar,
                                  m_a=2 * u.m_a, t_comm=t_comm)
    return StageTimes.uniform(2 * p, t_f=u.t_f, t_b=u.t_b, t_w=u.t_w,
                              t_ar=u.t_ar, m_a=u.m_a, t_comm=t_comm)


@pytest.mark.parametrize("kind", sch.SCHEDULES)
@pytest.mark.parametrize("p,m", [(2, 8), (4, 12), (8, 16), (4, 64)])
def test_schedule_valid_and_complete(kind, p, m):
    u = UnitTimes()
    res, tables, pl = sch.run(kind, p, m, times_for(kind, p, u))
    assert res.total_time > 0
    # ideal work is a lower bound; 3x is a generous sanity ceiling
    ideal = ideal_time(p, m, u)
    assert ideal <= res.total_time < 3 * ideal + 100


@pytest.mark.parametrize("kind", sch.SCHEDULES)
@pytest.mark.parametrize("p,m", [(2, 8), (4, 12), (8, 16), (4, 64)])
def test_ir_verifier_conformance(kind, p, m):
    """Static IR verification of every schedule table: dependencies
    satisfiable without deadlock, no double-free of activations or weight
    tapes, nothing leaked, and per-device peak in-flight activations within
    the schedule's Table-1 memory bound."""
    tables, pl = sch.build(kind, p, m)
    peak = verify_tables(tables, pl, m,
                         mem_bound=sch.memory_bound(kind, p, m))
    assert peak.max() > 0


def test_ir_verifier_rejects_malformed():
    p, m = 2, 4
    tables, pl = sch.build("stp", p, m)
    # duplicate op (also covers double-issue of a B/W)
    bad = [list(t) for t in tables]
    bad[0] = bad[0] + [bad[0][0]]
    with pytest.raises(ScheduleVerificationError, match="duplicate"):
        verify_tables(bad, pl, m)
    # incomplete schedule (a dropped W leaks its tape)
    bad = [list(t) for t in tables]
    w_at = next(i for i, ins in enumerate(bad[0])
                if ins.kind == "W")
    del bad[0][w_at]
    with pytest.raises(ScheduleVerificationError, match="incomplete"):
        verify_tables(bad, pl, m)
    # dependency deadlock: a full backward hoisted before its own forward
    gt, gpl = sch.build("gpipe", 2, 2)
    bad = [list(t) for t in gt]
    bad[0].insert(0, bad[0].pop(2))           # BW(0,0) before F(0,0)
    with pytest.raises(ScheduleVerificationError, match="deadlock"):
        verify_tables(bad, gpl, 2)
    # memory bound violation
    with pytest.raises(ScheduleVerificationError, match="exceeds"):
        verify_tables(tables, pl, m, mem_bound=1.0)


@pytest.mark.parametrize("p,m", [(2, 16), (4, 16), (8, 48)])
def test_table1_memory(p, m):
    """Peak activation memory matches Table 1 (+1 M_a transient slack: the
    braided/1F1B F executes before its paired B releases)."""
    u = UnitTimes()
    for kind, key in [("1f1b-i", "1f1b-i"), ("zb-v", "zb-v"), ("stp", "stp")]:
        res, _, _ = sch.run(kind, p, m, times_for(kind, p, u))
        th = THEORY[key](p, m, u).peak_act_memory
        assert res.peak_mem.max() <= th + 1.0 + 1e-9, (kind, res.peak_mem)
        assert res.peak_mem.max() >= th - 2.0, (kind, res.peak_mem)


@pytest.mark.parametrize("p,m", [(2, 16), (4, 16)])
def test_table1_tp_bubble(p, m):
    """Exposed TP communication: exact for 1F1B-I (2m·T_AR) and ZB-V
    (4m·T_AR); STP stays within 2x of the (2p+1)·T_AR closed form and far
    below both baselines."""
    u = UnitTimes()
    res_i, _, _ = sch.run("1f1b-i", p, m, times_for("1f1b-i", p, u))
    res_z, _, _ = sch.run("zb-v", p, m, times_for("zb-v", p, u))
    res_s, _, _ = sch.run("stp", p, m, times_for("stp", p, u))
    assert res_i.tp_exposed.mean() == pytest.approx(2 * m * u.t_ar)
    assert res_z.tp_exposed.mean() == pytest.approx(4 * m * u.t_ar)
    th_s = THEORY["stp"](p, m, u).tp_bubble
    assert res_s.tp_exposed.mean() <= 2 * th_s + 1e-9
    assert res_s.tp_exposed.mean() < 0.4 * res_i.tp_exposed.mean()


def test_memory_balance_vshape():
    """§4.1: V-shape flow balances peak memory; 1F1B-I peaks on device 0 and
    decreases with stage index."""
    u = UnitTimes()
    p, m = 4, 32
    res_i, _, _ = sch.run("1f1b-i", p, m, times_for("1f1b-i", p, u))
    res_z, _, _ = sch.run("zb-v", p, m, times_for("zb-v", p, u))
    res_s, _, _ = sch.run("stp", p, m, times_for("stp", p, u))
    assert all(np.diff(res_i.peak_mem) < 0)           # strictly decreasing
    assert res_z.peak_mem.max() - res_z.peak_mem.min() <= 1.0
    assert res_s.peak_mem.max() - res_s.peak_mem.min() <= 2.0


@pytest.mark.parametrize("p,m,t_ar", [(2, 64, 1.1), (4, 64, 0.55),
                                      (8, 96, 0.55)])
def test_throughput_ordering(p, m, t_ar):
    """§5.2: STP beats 1F1B-I and ZB-V; ZB-V is comparable-or-worse than
    1F1B-I once TP bubbles are accounted (the paper's key observation)."""
    u = UnitTimes(t_ar=t_ar)
    tot = {}
    for kind in ("1f1b-i", "zb-v", "stp"):
        res, _, _ = sch.run(kind, p, m, times_for(kind, p, u))
        tot[kind] = res.total_time
    assert tot["stp"] < tot["1f1b-i"] < tot["zb-v"]


def test_improvement_band_tp8_pp2():
    """Paper headline: largest wins at TP=8 (large T_AR share), PP=2 —
    'up to 12%' vs 1F1B-I.  Our idealized braiding caps the exposure at the
    schedule optimum, so the simulated gain must be at least that and
    within a sane bound."""
    u = UnitTimes(t_ar=1.1)
    p, m = 2, 64
    res_i, _, _ = sch.run("1f1b-i", p, m, times_for("1f1b-i", p, u))
    res_s, _, _ = sch.run("stp", p, m, times_for("stp", p, u))
    gain = res_i.total_time / res_s.total_time - 1.0
    assert 0.10 <= gain <= 0.30, gain


def test_stp_memeff_tradeoff():
    """App. A/B schedule (d): lower peak memory, some tail bubbles."""
    u = UnitTimes()
    p, m = 4, 24
    res_s, _, _ = sch.run("stp", p, m, times_for("stp", p, u))
    res_d, _, _ = sch.run("stp-memeff", p, m, times_for("stp-memeff", p, u))
    assert res_d.peak_mem.max() < res_s.peak_mem.max()
    assert res_d.total_time >= res_s.total_time


def test_offload_variant():
    """§4.4 / Fig. 10: offloading cuts peak memory 10-20% at negligible
    throughput cost."""
    u = UnitTimes()
    p, m = 4, 24
    tables, pl = sch.build("stp", p, m, times_for("stp", p, u))
    t = times_for("stp", p, u)
    base = simulate(tables, pl, t, m)
    off = simulate(tables, pl, t, m, offload_alpha=0.4,
                   offload_overhead=0.02)
    red = 1 - off.peak_mem.max() / base.peak_mem.max()
    assert 0.08 <= red <= 0.35, red
    assert off.total_time <= base.total_time * 1.03


def test_mllm_imbalanced_vit():
    """§5.3: with a ViT-heavy first virtual stage (MLLM), STP still wins and
    the same-chunk braiding (pattern 2) keeps exposure low."""
    p, m = 2, 32
    u = UnitTimes(t_ar=0.8)
    t = StageTimes.uniform(2 * p, t_f=u.t_f, t_b=u.t_b, t_w=u.t_w,
                           t_ar=u.t_ar, m_a=u.m_a).scaled_vs(0, 1.8)
    tot = {}
    for kind in ("1f1b-i", "zb-v", "stp"):
        res, tables, pl = sch.run(kind, p, m, t)
        tot[kind] = res.total_time
    assert tot["stp"] < min(tot["1f1b-i"], tot["zb-v"])


def test_replay_matches_generation():
    """The recorded greedy table replayed through `simulate` is feasible and
    deterministic."""
    u = UnitTimes()
    p, m = 4, 16
    t = times_for("stp", p, u)
    tables, pl = sch.build("stp", p, m, t)
    r1 = simulate(tables, pl, t, m)
    r2 = simulate(tables, pl, t, m)
    assert r1.total_time == r2.total_time
    sch.validate(tables, pl, m)


def test_pipeline_requires_two_stages():
    """p=1 must fail loudly at both entry points (a single-stage pipeline
    has no neighbour exchange; the SPMD executor would silently zero its
    boundary streams)."""
    from repro.core.simulator import flat
    with pytest.raises(ValueError, match="p >= 2"):
        sch.build("gpipe", 1, 4)
    with pytest.raises(ValueError, match="p >= 2"):
        flat(1)


def test_segment_grid_pins_gpipe():
    """The fused lowering's segment partition of the gpipe p=2 m=4 grid:
    maximal constant-role runs with statically-dead streams elided (the
    forward half only ships activations up, the backward half only ships
    gradients down)."""
    from repro.pipeline import slots as SL
    tables, pl = sch.build("gpipe", 2, 4)
    codes = SL.encode(SL.to_slots(tables, pl), pl)
    segs = SL.segment_grid(codes, pl.kind)
    assert [(s.start, s.stop) for s in segs] == \
        [(0, 1), (1, 4), (4, 5), (5, 6), (6, 9), (9, 10)]
    assert [(sorted(s.live_up), sorted(s.live_dn)) for s in segs] == \
        [(["x0"], []), (["x0"], []), ([], []),
         ([], ["g0"]), ([], ["g0"]), ([], [])]
    stats = SL.plan_stats(codes, pl.kind, fused=True)
    gen = SL.plan_stats(codes, pl.kind, fused=False)
    assert stats == {"n_slots": 10, "n_segments": 6, "n_dispatches": 10,
                     "n_ppermutes": 8}
    assert gen["n_dispatches"] == 30 and gen["n_ppermutes"] == 40


def test_segment_grid_pins_zbv():
    """ZB-V's p=2 m=4 grid has no repeated rows — every segment is length
    one (inlined straight-line code, no scan) — and exactly one slot is
    role-uniform across devices (no switch at all).  Liveness pruning still
    cuts the exchanged tensors by 13x vs the generic (payload, flag) wiring."""
    from repro.pipeline import slots as SL
    tables, pl = sch.build("zb-v", 2, 4)
    codes = SL.encode(SL.to_slots(tables, pl), pl)
    segs = SL.segment_grid(codes, pl.kind)
    assert len(codes) == 26
    assert all(s.length == 1 for s in segs)
    assert sum(1 for s in segs if s.n_rows == 1) == 1
    stats = SL.plan_stats(codes, pl.kind, fused=True)
    gen = SL.plan_stats(codes, pl.kind, fused=False)
    assert stats["n_dispatches"] == 25 and stats["n_ppermutes"] == 16
    assert gen["n_dispatches"] == 78 and gen["n_ppermutes"] == 208


def test_segment_grid_periodic_steady_state():
    """Steady-state braids fold into periodic segments, so the traced
    program stops growing with m: 1f1b's F,BW alternation is one period-2
    scan covering 2(m-p) slots, and the vshape kinds' braids fold at m=8+.
    Dispatch/ppermute counts are per-executed-slot and must not change."""
    from repro.pipeline import slots as SL

    tables, pl = sch.build("1f1b", 2, 16)
    codes = SL.encode(SL.to_slots(tables, pl), pl)
    segs = SL.segment_grid(codes, pl.kind)
    per = [s for s in segs if s.period > 1]
    assert [(s.start, s.stop, s.period) for s in per] == [(3, 31, 2)]
    (s,) = per
    assert s.n_iters == 14 and len(s.phases) == 2
    # phase liveness is pruned per phase, not unioned over the segment
    assert [tuple(map(tuple, lv)) for lv in s.live] == \
        [((), ()), (("x0",), ("g0",))]
    # receive rows come per phase, one (n_iters, p, n_live) array each
    rr = SL.recv_rows(codes, s, pl.kind, m=16)
    assert [a.shape for a in rr] == [(14, 2, 0), (14, 2, 2)]
    # the scan repeats the slot work, so per-step counters are unchanged
    # by the periodic folding: 2 braid slots per iteration
    stats = SL.plan_stats(codes, pl.kind, fused=True)
    assert stats["n_segments"] == 7          # independent of m
    assert stats["n_slots"] == 34

    for kind, m in (("stp", 8), ("zb-v", 8), ("stp-memeff", 8)):
        tables, pl = sch.build(kind, 2, m)
        codes = SL.encode(SL.to_slots(tables, pl), pl)
        assert any(s.period > 1
                   for s in SL.segment_grid(codes, pl.kind)), kind


# ---------------------------------------------------------------------------
# Cost-balanced layer partitioning (core.schedule.partition).
# ---------------------------------------------------------------------------

def _part_cfg(n_layers):
    from repro.configs import get_config
    return get_config("qwen3-4b").reduced(n_layers=n_layers, d_model=64,
                                          n_heads=4, vocab=128)


def _brute_bottleneck(costs, n_vs, weight):
    """Exhaustive min over contiguous partitions of max weighted stage cost."""
    import itertools
    n = len(costs)
    best = float("inf")
    for cuts in itertools.combinations(range(1, n), n_vs - 1):
        bnds = [0, *cuts, n]
        mx = max(w * sum(costs[a:b])
                 for w, (a, b) in zip(weight, zip(bnds, bnds[1:])))
        best = min(best, mx)
    return best


@pytest.mark.parametrize("n,n_vs", [(4, 2), (7, 3), (10, 4), (12, 4),
                                    (9, 8), (5, 5)])
def test_partition_uniform_costs_match_uniform_ranges(n, n_vs):
    """With homogeneous layers the cost-balanced split must reproduce the
    naive near-uniform baseline exactly (the earliest-heavy tie-break)."""
    cfg = _part_cfg(n)
    assert sch.partition(cfg, n_vs) == sch.uniform_ranges(n, n_vs)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("n,n_vs,vit", [(9, 3, 1.0), (10, 4, 1.0),
                                        (10, 4, 3.0), (8, 4, 0.5)])
def test_partition_bottleneck_optimal(seed, n, n_vs, vit):
    """The two-pass DP attains the exact brute-force bottleneck under
    arbitrary per-layer costs and stage-0 weighting."""
    rng = np.random.default_rng(seed)
    costs = rng.uniform(0.5, 4.0, size=n).tolist()
    weight = [vit if s == 0 else 1.0 for s in range(n_vs)]
    part = sch.partition(_part_cfg(n), n_vs, vit_factor=vit, costs=costs)
    assert part[0][0] == 0 and part[-1][1] == n
    assert all(a < b for a, b in part)
    got = max(w * sum(costs[a:b]) for w, (a, b) in zip(weight, part))
    want = _brute_bottleneck(costs, n_vs, weight)
    assert got <= want + 1e-9


def test_partition_vit_factor_lightens_stage0():
    """A heavy resident ViT frontend (vit_factor > 1) must shed layers from
    virtual stage 0 relative to the uniform split."""
    cfg = _part_cfg(12)
    base = sch.partition(cfg, 4)
    vit = sch.partition(cfg, 4, vit_factor=4.0)
    assert base[0][1] - base[0][0] == 3
    assert vit[0][1] - vit[0][0] < 3


def test_partition_explicit_ranges():
    cfg = _part_cfg(6)
    part = ((0, 1), (1, 5), (5, 6))
    assert sch.partition(cfg, 3, ranges=part) == part
    with pytest.raises(ValueError):            # gap
        sch.partition(cfg, 3, ranges=((0, 1), (2, 5), (5, 6)))
    with pytest.raises(ValueError):            # wrong count
        sch.partition(cfg, 3, ranges=((0, 3), (3, 6)))
    with pytest.raises(ValueError):            # not covering
        sch.partition(cfg, 3, ranges=((0, 1), (1, 2), (2, 5)))
    # empty stage allowed in explicit mode (reference executor only)
    assert sch.partition(cfg, 3, ranges=((0, 3), (3, 3), (3, 6)))[1] == (3, 3)


def test_partition_degenerate_fewer_layers_than_stages():
    """n < n_vs: one layer per early stage, empty tails (legacy rule used
    by the smoke-scale reference-executor tests)."""
    part = sch.partition(_part_cfg(2), 4)
    assert part == ((0, 1), (1, 2), (2, 2), (2, 2))


def test_moe_layer_cost_counts_active_experts_only():
    """layer_cost must charge top_k expert FFNs, not all E of them — else
    MoE-heavy stages would be wildly over-weighted."""
    from repro.configs import get_config
    cfg = get_config("olmoe-1b-7b")          # 64 experts, top_k 8
    moe_c = sch.layer_cost(cfg.layers[0], cfg)
    full = cfg.moe.num_experts * 3 * cfg.d_model * cfg.moe.d_ff
    assert moe_c < full / 4
