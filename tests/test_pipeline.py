"""Pipeline executor equivalence: the braided F/B/W schedule execution must
reproduce ``jax.grad`` exactly — for every schedule kind, across
architecture families, and on a real multi-device stage (and stage x model)
mesh (subprocess: device count must be fixed before jax initializes)."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.schedule import SCHEDULES, build
from repro.models import model as M
from repro.pipeline.reference import pipeline_grads, reference_grads

REPO = Path(__file__).resolve().parent.parent


def make_batches(cfg, key, m, b, s):
    ks = jax.random.split(key, m)
    out = []
    for k in ks:
        lab = jax.random.randint(k, (b, s), 0, cfg.vocab)
        if cfg.frontend == "text":
            out.append({"tokens": jax.random.randint(k, (b, s), 0, cfg.vocab),
                        "labels": lab})
        else:
            out.append({"embeds": jax.random.normal(k, (b, s, cfg.d_model)),
                        "labels": lab})
    return out


def rel_err(g, g_ref):
    fp, tp_ = jax.tree.flatten(g)
    fr, tr = jax.tree.flatten(g_ref)
    assert tr == tp_
    return max(float(np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9))
               for a, b in zip(fp, fr))


@pytest.mark.parametrize("kind", SCHEDULES)
def test_reference_executor_matches_grad(kind):
    cfg = get_config("qwen3-4b").reduced(n_layers=4, d_model=64, n_heads=4,
                                         vocab=128)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    batches = make_batches(cfg, key, m=6, b=2, s=16)
    loss_ref, g_ref = reference_grads(params, batches, cfg)
    tables, pl = build(kind, 2, len(batches))
    loss, g = pipeline_grads(params, batches, tables, pl, cfg)
    assert np.allclose(loss, loss_ref, rtol=1e-5)
    assert rel_err(g, g_ref) < 1e-4


@pytest.mark.parametrize("arch,extra", [
    ("olmoe-1b-7b", {}),                         # MoE unit path
    ("xlstm-125m", {"n_layers": 4}),             # sLSTM + mLSTM scan cores
    ("jamba-1.5-large-398b", {"n_layers": 4}),   # mamba + MoE hybrid
    ("hubert-xlarge", {}),                       # encoder-only, layernorm
    ("gemma3-12b", {}),                          # sliding window + GeGLU
    ("llava-next-mistral-7b", {}),               # embed frontend
])
def test_stp_executor_across_families(arch, extra):
    cfg = get_config(arch).reduced(n_layers=extra.get("n_layers", 2),
                                   d_model=64, n_heads=4, vocab=128)
    key = jax.random.PRNGKey(1)
    params = M.init_params(key, cfg)
    batches = make_batches(cfg, key, m=4, b=2, s=16)
    loss_ref, g_ref = reference_grads(params, batches, cfg)
    tables, pl = build("stp", 2, len(batches))
    loss, g = pipeline_grads(params, batches, tables, pl, cfg)
    assert np.allclose(loss, loss_ref, rtol=1e-5), (loss, loss_ref)
    assert rel_err(g, g_ref) < 2e-4


def _run_sub(script: str):
    r = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        cwd=str(REPO), env={"PYTHONPATH": str(REPO / "src"),
                            "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"},
        timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


SPMD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_config
from repro.core.schedule import build, memory_bound
from repro.core.simulator import verify_tables
from repro.models import model as M
from repro.pipeline.reference import pipeline_grads, reference_grads
from repro.pipeline.spmd import (build_pipeline_step, stack_stage_params,
                                 unstack_stage_grads)

p, tp_size, m = {p}, {tp}, {m}
tables, pl = build("{kind}", p, m)
verify_tables(tables, pl, m, mem_bound=memory_bound("{kind}", p, m))
cfg = get_config("qwen3-4b").reduced(n_layers=pl.n_vs, d_model=64,
                                     n_heads=4, vocab=128)
key = jax.random.PRNGKey(0)
params = M.init_params(key, cfg)
b, s = 2, 16
ks = jax.random.split(key, m)
batches = [{{"tokens": jax.random.randint(k, (b, s), 0, cfg.vocab),
            "labels": jax.random.randint(k, (b, s), 0, cfg.vocab)}}
           for k in ks]

def rel(g, g_ref):
    fp, tp_ = jax.tree.flatten(g)
    fr, tr = jax.tree.flatten(g_ref)
    assert tr == tp_
    return max(float(np.max(np.abs(a - bb)) / (np.max(np.abs(bb)) + 1e-9))
               for a, bb in zip(fp, fr))

# differential: jax.grad oracle, (slow tier) reference table executor, SPMD
loss_ref, g_ref = reference_grads(params, batches, cfg)
if {with_ref}:
    loss_tab, g_tab = pipeline_grads(params, batches, tables, pl, cfg)
    assert np.allclose(loss_tab, loss_ref, rtol=1e-5), (loss_tab, loss_ref)
    assert rel(g_tab, g_ref) < 1e-4

mesh = Mesh(np.array(jax.devices()).reshape(p, tp_size), ("stage", "model"))
c0, c1, lvs = stack_stage_params(params, cfg, p, kind=pl.kind)
step = build_pipeline_step(cfg, tables, pl, mesh, m, (b, s),
                           (c0, c1, params["embed"], params["head"]),
                           model_axis={model_axis})
tokens = jnp.stack([bb["tokens"] for bb in batches])
labels = jnp.stack([bb["labels"] for bb in batches])
with mesh:
    loss, g0, g1, ge, gh = step(c0, c1, params["embed"], params["head"],
                                tokens, labels)
assert np.allclose(loss, loss_ref, rtol=1e-5), (loss, loss_ref)
blocks = unstack_stage_grads(jax.device_get(g0), jax.device_get(g1),
                             cfg, p, lvs, kind=pl.kind)
g = {{"embed": jax.device_get(ge), "blocks": blocks,
     "head": jax.device_get(gh)}}
err = rel(g, g_ref)
assert err < 1e-4, err
print("OK", float(loss), err)
"""


def _spmd_case(kind, p, tp, m, ndev=4, with_ref=True):
    script = SPMD_SCRIPT.format(
        ndev=ndev, p=p, tp=tp, m=m, kind=kind,
        model_axis='"model"' if tp > 1 else "None",
        with_ref="True" if with_ref else "False")
    out = _run_sub(script)
    assert "OK" in out


@pytest.mark.parametrize("kind,p,tp,ndev", [
    ("stp", 2, 2, 4),          # synergistic TP x PP (the paper's setting)
])
def test_spmd_executor_multidevice(kind, p, tp, ndev):
    # no reference-executor pass here: keeps the unmarked (fast-tier) case
    # at its original cost; the slow tier runs the full three-way diff.
    _spmd_case(kind, p, tp, m=6, ndev=ndev, with_ref=False)


@pytest.mark.slow
@pytest.mark.parametrize("kind,p,tp,m", [
    ("gpipe", 4, 1, 4),        # flat placement, pure PP
    ("gpipe", 2, 2, 4),        # flat placement composed with TP
    ("1f1b", 4, 1, 6),
    ("1f1b", 2, 2, 6),
    ("1f1b-i", 4, 1, 8),       # parallel placement (wrap-around ring)
    ("1f1b-i", 2, 2, 4),
    ("zb-v", 4, 1, 6),         # vshape at full stage depth
    ("zb-v", 2, 2, 6),
    ("stp", 4, 1, 6),          # pure PP, 4 stages
    ("stp-memeff", 4, 1, 6),
    ("stp-memeff", 2, 2, 6),
])
def test_spmd_executor_all_schedules(kind, p, tp, m):
    """Differential conformance over every placement family: the SPMD
    shard_map runtime must match both the reference table executor and the
    monolithic jax.grad oracle for every schedule kind on a real 4-device
    (stage x model) mesh."""
    _spmd_case(kind, p, tp, m)


# ---------------------------------------------------------------------------
# Fused (segment) lowering vs generic one-switch-per-slot scan.
# ---------------------------------------------------------------------------

FUSE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_config
from repro.core.schedule import build
from repro.models import model as M
from repro.pipeline.spmd import build_pipeline_step, stack_stage_params

p, m = 2, {m}
tables, pl = build("{kind}", p, m)
cfg = get_config("qwen3-4b").reduced(n_layers=pl.n_vs, d_model=64,
                                     n_heads=4, vocab=128)
key = jax.random.PRNGKey(0)
params = M.init_params(key, cfg)
b, s = 2, 16
ks = jax.random.split(key, m)
tokens = jnp.stack([jax.random.randint(k, (b, s), 0, cfg.vocab)
                    for k in ks])
labels = jnp.stack([jax.random.randint(k, (b, s), 0, cfg.vocab)
                    for k in ks])
mesh = Mesh(np.array(jax.devices()).reshape(p, 1), ("stage", "model"))
c0, c1, lvs = stack_stage_params(params, cfg, p, kind=pl.kind)
stacked = (c0, c1, params["embed"], params["head"])
outs = {{}}
for fuse in (False, True):
    step = build_pipeline_step(cfg, tables, pl, mesh, m, (b, s), stacked,
                               fuse_slots=fuse)
    with mesh:
        outs[fuse] = [np.asarray(x) for x in jax.tree.leaves(
            step(*stacked, tokens, labels))]
err = max(float(np.max(np.abs(a - g)) / (np.max(np.abs(g)) + 1e-9))
          for a, g in zip(outs[True], outs[False]))
assert err < 1e-5, err
print("OK", err)
"""


def _fuse_case(kind, m=4):
    out = _run_sub(FUSE_SCRIPT.format(kind=kind, m=m))
    assert "OK" in out


@pytest.mark.parametrize("kind", ["1f1b"])
def test_fused_matches_generic(kind):
    """Loss + every grad from the segment-fused lowering must match the
    generic one-switch-per-slot scan to < 1e-5.  One cheap flat-placement
    case rides in the fast tier (1f1b at m=4 already contains a period-2
    steady-state segment, so the periodic scan path is exercised); the
    slow tier completes the matrix (all six kinds, so every placement
    family's wiring is pinned, with m=8 on the vshape kinds so their
    braids fold into periodic segments too)."""
    _fuse_case(kind)


@pytest.mark.slow
@pytest.mark.parametrize("kind,m", [("gpipe", 4), ("1f1b-i", 4),
                                    ("zb-v", 8), ("stp", 8),
                                    ("stp-memeff", 8)])
def test_fused_matches_generic_slow(kind, m):
    """Remaining schedule kinds of the fused-vs-generic differential."""
    _fuse_case(kind, m)


# ---------------------------------------------------------------------------
# Heterogeneous per-stage partitions (cost-balanced / explicit ranges).
# ---------------------------------------------------------------------------

def test_reference_executor_nonuniform_partition():
    """Explicit non-uniform layer ranges through the reference table
    executor must still reproduce jax.grad (satellite of the shared
    core.schedule.partition refactor)."""
    cfg = get_config("qwen3-4b").reduced(n_layers=8, d_model=64, n_heads=4,
                                         vocab=128)
    key = jax.random.PRNGKey(2)
    params = M.init_params(key, cfg)
    batches = make_batches(cfg, key, m=4, b=2, s=16)
    loss_ref, g_ref = reference_grads(params, batches, cfg)
    tables, pl = build("stp", 2, len(batches))          # n_vs = 4
    part = ((0, 1), (1, 4), (4, 6), (6, 8))
    loss, g = pipeline_grads(params, batches, tables, pl, cfg, part=part)
    assert np.allclose(loss, loss_ref, rtol=1e-5)
    assert rel_err(g, g_ref) < 1e-4


PART_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_config
from repro.core.schedule import build
from repro.models import model as M
from repro.pipeline.reference import pipeline_grads, reference_grads
from repro.pipeline.spmd import (build_pipeline_step, stack_stage_params,
                                 unstack_stage_grads)

kind, p, m = "{kind}", {p}, 4
part = ((0, 1), (1, 4), (4, 7), (7, 10))        # n_vs = 4, sizes 1/3/3/3
cfg = get_config("qwen3-4b").reduced(n_layers=10, d_model=64, n_heads=4,
                                     vocab=128)
key = jax.random.PRNGKey(0)
params = M.init_params(key, cfg)
b, s = 2, 16
ks = jax.random.split(key, m)
batches = [{{"tokens": jax.random.randint(k, (b, s), 0, cfg.vocab),
            "labels": jax.random.randint(k, (b, s), 0, cfg.vocab)}}
           for k in ks]

def rel(g, gr):
    fa = jnp.concatenate([x.ravel() for x in jax.tree.leaves(g)])
    fb = jnp.concatenate([x.ravel() for x in jax.tree.leaves(gr)])
    return float(jnp.linalg.norm(fa - fb) / (jnp.linalg.norm(fb) + 1e-12))

loss_ref, g_ref = reference_grads(params, batches, cfg)
tables, pl = build(kind, p, m)
lossr, gr = pipeline_grads(params, batches, tables, pl, cfg, part=part)
assert abs(float(lossr) - float(loss_ref)) < 1e-5, (lossr, loss_ref)
assert rel(gr, g_ref) < 1e-4

mesh = Mesh(np.array(jax.devices()[:p]).reshape(p, 1)[:, 0], ("stage",))
c0, c1, bounds = stack_stage_params(params, cfg, p, kind=pl.kind, part=part)
trees = (c0, c1, params["embed"], params["head"])
tokens = jnp.stack([bt["tokens"] for bt in batches])
labels = jnp.stack([bt["labels"] for bt in batches])
for fuse in (True, False):
    step = build_pipeline_step(cfg, tables, pl, mesh, m, (b, s), trees,
                               fuse_slots=fuse, part=part)
    with mesh:
        loss, g0, g1, ge, gh = step(c0, c1, params["embed"], params["head"],
                                    tokens, labels)
    gb = unstack_stage_grads(jax.device_get(g0), jax.device_get(g1), cfg, p,
                             bounds, kind=pl.kind)
    gsp = {{"embed": jax.device_get(ge), "blocks": gb,
           "head": jax.device_get(gh)}}
    assert abs(float(loss) - float(loss_ref)) < 1e-5, (fuse, loss, loss_ref)
    e = rel(gsp, g_ref)
    assert e < 1e-4, (fuse, e)
print("OK")
"""


def _part_case(kind, p):
    out = _run_sub(PART_SCRIPT.format(kind=kind, p=p))
    assert "OK" in out


@pytest.mark.parametrize("kind,p", [("stp", 2)])
def test_spmd_nonuniform_partition(kind, p):
    """Three-way differential (jax.grad / reference executor / SPMD, both
    lowerings) on a 1/3/3/3 partition of 10 layers.  One vshape case rides
    the fast tier; the slow tier sweeps every placement family."""
    _part_case(kind, p)


@pytest.mark.slow
@pytest.mark.parametrize("kind,p", [("gpipe", 4), ("1f1b", 4),
                                    ("1f1b-i", 2), ("zb-v", 2),
                                    ("stp-memeff", 2)])
def test_spmd_nonuniform_partition_slow(kind, p):
    """Remaining schedule kinds of the non-uniform-partition differential."""
    _part_case(kind, p)


# ---------------------------------------------------------------------------
# Expert parallelism: ep=2 must train bit-for-bit like ep=1.
# ---------------------------------------------------------------------------

EP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
from jax.sharding import Mesh
from repro.configs import get_config
from repro.data import DataConfig, make_batches
from repro.launch.runner import make_runner
from repro.models import model as M
from repro.optim import OptConfig

cfg = get_config("olmoe-1b-7b").reduced(n_layers=2, d_model=64, n_heads=4,
                                        vocab=128)
dc = DataConfig(global_batch=4, microbatches=4, seq_len=16)
oc = OptConfig()
params = M.init_params(jax.random.PRNGKey(0), cfg)
batches = list(make_batches(cfg, dc, 2))

def run(ep):
    mesh = (Mesh(np.array(jax.devices()[:2]).reshape(2, 1),
                 ("stage", "model")) if ep == 1 else None)
    r = make_runner("spmd", cfg, oc, dc, schedule="1f1b", pp=2, tp=1,
                    ep=ep, mesh=mesh)
    st = r.init_state(params)
    out = []
    for b in batches:
        st, met = r.step(st, b)
        out.append((float(met["loss"]), float(met["gnorm"])))
    p2, _ = st.to_canonical()
    return out, p2

m1, p1 = run(1)
m2, p2 = run(2)
for (l1, g1), (l2, g2) in zip(m1, m2):
    assert abs(l1 - l2) < 1e-4 and abs(g1 - g2) < 1e-4, (l1, l2, g1, g2)
fa = np.concatenate([np.ravel(x) for x in jax.tree.leaves(p1)])
fb = np.concatenate([np.ravel(x) for x in jax.tree.leaves(p2)])
err = float(np.max(np.abs(fa - fb)))
assert err < 1e-4, err
print("OK", err)
"""


def test_spmd_expert_parallel_matches_ep1():
    """Training the MoE arch with the expert axis (pp=2 x ep=2 on 4 fake
    devices) must match pp=2 ep=1 — routing is replicated across the
    expert group, so losses, grad norms, and the updated params after two
    AdamW steps agree to < 1e-4 (bitwise on CPU)."""
    out = _run_sub(EP_SCRIPT)
    assert "OK" in out
