"""Pipeline executor equivalence: the braided F/B/W schedule execution must
reproduce ``jax.grad`` exactly — for every schedule kind, across
architecture families, and on a real multi-device stage (and stage x model)
mesh (subprocess: device count must be fixed before jax initializes)."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.schedule import SCHEDULES, build
from repro.models import model as M
from repro.pipeline.reference import pipeline_grads, reference_grads

REPO = Path(__file__).resolve().parent.parent


def make_batches(cfg, key, m, b, s):
    ks = jax.random.split(key, m)
    out = []
    for k in ks:
        lab = jax.random.randint(k, (b, s), 0, cfg.vocab)
        if cfg.frontend == "text":
            out.append({"tokens": jax.random.randint(k, (b, s), 0, cfg.vocab),
                        "labels": lab})
        else:
            out.append({"embeds": jax.random.normal(k, (b, s, cfg.d_model)),
                        "labels": lab})
    return out


def rel_err(g, g_ref):
    fp, tp_ = jax.tree.flatten(g)
    fr, tr = jax.tree.flatten(g_ref)
    assert tr == tp_
    return max(float(np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9))
               for a, b in zip(fp, fr))


@pytest.mark.parametrize("kind", SCHEDULES)
def test_reference_executor_matches_grad(kind):
    cfg = get_config("qwen3-4b").reduced(n_layers=4, d_model=64, n_heads=4,
                                         vocab=128)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    batches = make_batches(cfg, key, m=6, b=2, s=16)
    loss_ref, g_ref = reference_grads(params, batches, cfg)
    tables, pl = build(kind, 2, len(batches))
    loss, g = pipeline_grads(params, batches, tables, pl, cfg)
    assert np.allclose(loss, loss_ref, rtol=1e-5)
    assert rel_err(g, g_ref) < 1e-4


@pytest.mark.parametrize("arch,extra", [
    ("olmoe-1b-7b", {}),                         # MoE unit path
    ("xlstm-125m", {"n_layers": 4}),             # sLSTM + mLSTM scan cores
    ("jamba-1.5-large-398b", {"n_layers": 4}),   # mamba + MoE hybrid
    ("hubert-xlarge", {}),                       # encoder-only, layernorm
    ("gemma3-12b", {}),                          # sliding window + GeGLU
    ("llava-next-mistral-7b", {}),               # embed frontend
])
def test_stp_executor_across_families(arch, extra):
    cfg = get_config(arch).reduced(n_layers=extra.get("n_layers", 2),
                                   d_model=64, n_heads=4, vocab=128)
    key = jax.random.PRNGKey(1)
    params = M.init_params(key, cfg)
    batches = make_batches(cfg, key, m=4, b=2, s=16)
    loss_ref, g_ref = reference_grads(params, batches, cfg)
    tables, pl = build("stp", 2, len(batches))
    loss, g = pipeline_grads(params, batches, tables, pl, cfg)
    assert np.allclose(loss, loss_ref, rtol=1e-5), (loss, loss_ref)
    assert rel_err(g, g_ref) < 2e-4


def _run_sub(script: str):
    r = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        cwd=str(REPO), env={"PYTHONPATH": str(REPO / "src"),
                            "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"},
        timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


SPMD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_config
from repro.core.schedule import build
from repro.models import model as M
from repro.pipeline.reference import reference_grads
from repro.pipeline.spmd import (build_pipeline_step, stack_stage_params,
                                 unstack_stage_grads)

p, tp_size = {p}, {tp}
cfg = get_config("qwen3-4b").reduced(n_layers=2*p, d_model=64, n_heads=4,
                                     vocab=128)
key = jax.random.PRNGKey(0)
params = M.init_params(key, cfg)
m, b, s = {m}, 2, 16
ks = jax.random.split(key, m)
batches = [{{"tokens": jax.random.randint(k, (b, s), 0, cfg.vocab),
            "labels": jax.random.randint(k, (b, s), 0, cfg.vocab)}}
           for k in ks]
loss_ref, g_ref = reference_grads(params, batches, cfg)
mesh = Mesh(np.array(jax.devices()).reshape(p, tp_size), ("stage", "model"))
tables, pl = build("{kind}", p, m)
c0, c1, lvs = stack_stage_params(params, cfg, p)
step = build_pipeline_step(cfg, tables, pl, mesh, m, (b, s),
                           (c0, c1, params["embed"], params["head"]),
                           model_axis={model_axis})
tokens = jnp.stack([bb["tokens"] for bb in batches])
labels = jnp.stack([bb["labels"] for bb in batches])
with mesh:
    loss, g0, g1, ge, gh = step(c0, c1, params["embed"], params["head"],
                                tokens, labels)
assert np.allclose(loss, loss_ref, rtol=1e-5), (loss, loss_ref)
blocks = unstack_stage_grads(jax.device_get(g0), jax.device_get(g1),
                             cfg, p, lvs)
g = {{"embed": jax.device_get(ge), "blocks": blocks,
     "head": jax.device_get(gh)}}
fr, tr = jax.tree.flatten(g_ref)
fp, tp_ = jax.tree.flatten(g)
assert tr == tp_
err = max(float(np.max(np.abs(a - bb)) / (np.max(np.abs(bb)) + 1e-9))
          for a, bb in zip(fp, fr))
assert err < 1e-4, err
print("OK", float(loss), err)
"""


@pytest.mark.parametrize("kind,p,tp,ndev", [
    ("stp", 4, 1, 4),          # pure PP, 4 stages
    ("stp", 2, 2, 4),          # synergistic TP x PP (the paper's setting)
    ("zb-v", 2, 2, 4),
    ("stp-memeff", 2, 2, 4),
])
def test_spmd_executor_multidevice(kind, p, tp, ndev):
    script = SPMD_SCRIPT.format(
        ndev=ndev, p=p, tp=tp, m=6, kind=kind,
        model_axis='"model"' if tp > 1 else "None")
    out = _run_sub(script)
    assert "OK" in out
