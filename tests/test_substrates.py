"""Substrate behaviour: data pipeline, optimizer, checkpointing, serving
driver, and shardings helpers."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data import DataConfig, make_batches, microbatches
from repro.data.pipeline import SyntheticTextDataset, pack_documents
from repro.models import model as M
from repro.optim import OptConfig, adamw_init, adamw_update


def test_synthetic_data_learnable_structure():
    """The Markov corpus must be more predictable than uniform (otherwise
    the e2e training demo can't show loss decreasing)."""
    cfg = get_config("qwen3-4b").reduced(d_model=64, n_heads=4, vocab=128)
    ds = SyntheticTextDataset(cfg, DataConfig(seed=1))
    toks = ds.sample_tokens(4, 512)
    assert toks.shape == (4, 512)
    assert toks.min() >= 0 and toks.max() < 128
    # bigram predictability: repeated contexts share successors more often
    # than chance
    from collections import Counter, defaultdict
    succ = defaultdict(Counter)
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            succ[int(a)][int(b)] += 1
    top_mass = [c.most_common(1)[0][1] / sum(c.values())
                for c in succ.values() if sum(c.values()) >= 8]
    assert np.mean(top_mass) > 2.0 / 128


def test_data_shapes_and_masking():
    cfg_t = get_config("qwen3-4b").reduced(d_model=64, n_heads=4, vocab=128)
    dc = DataConfig(seq_len=32, global_batch=4)
    b = next(make_batches(cfg_t, dc, 1))
    assert b["tokens"].shape == (4, 32) and b["labels"].shape == (4, 32)
    # encoder-only masked prediction
    cfg_e = get_config("hubert-xlarge").reduced(d_model=64, n_heads=4,
                                                vocab=128)
    b = next(make_batches(cfg_e, dc, 1))
    assert b["embeds"].shape == (4, 32, 64)
    assert (b["labels"] >= 0).mean() < 0.5    # most positions unmasked


def test_pack_documents():
    docs = [np.arange(5), np.arange(7), np.arange(3)]
    packed = pack_documents(docs, seq=6, eod=999)
    assert packed.shape[1] == 6
    assert (packed == 999).sum() >= 2


def test_microbatch_split_roundtrip():
    batch = {"tokens": jnp.arange(32).reshape(8, 4)}
    mbs = microbatches(batch, 4)
    assert len(mbs) == 4 and mbs[0]["tokens"].shape == (2, 4)
    re = jnp.concatenate([m["tokens"] for m in mbs])
    np.testing.assert_array_equal(np.asarray(re),
                                  np.asarray(batch["tokens"]))


def test_adamw_descends_quadratic():
    oc = OptConfig(lr=0.1, warmup_steps=1, total_steps=100,
                   weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(60):
        g = {"w": 2 * params["w"]}
        params, state, gn = adamw_update(params, g, state, oc)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_grad_clip():
    from repro.optim.adamw import clip_by_global_norm
    g = {"a": jnp.full((10,), 100.0)}
    c, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) > 100
    norm = float(jnp.sqrt((c["a"] ** 2).sum()))
    assert abs(norm - 1.0) < 1e-5


def test_checkpoint_roundtrip():
    cfg = get_config("olmoe-1b-7b").reduced(d_model=64, n_heads=4, vocab=128)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, (params, opt), step=7, extra={"arch": cfg.name})
        zeros = jax.tree.map(jnp.zeros_like, (params, opt))
        (p2, o2), step, extra = load_checkpoint(d, zeros)
        assert step == 7 and extra["arch"] == cfg.name
        ref = jax.tree.leaves(params)
        got = jax.tree.leaves(p2)
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_mismatch_raises_valueerror():
    """A truncated or foreign checkpoint must fail loudly with ValueError —
    not a stripped-under-``-O`` assert, a bare KeyError, or (worst) a silent
    astype/reshape coercion of corrupted leaves."""
    from pathlib import Path

    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": jnp.arange(4, dtype=jnp.int32)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, tree, step=3)
        # leaf-count mismatch
        with pytest.raises(ValueError, match="leaf count"):
            load_checkpoint(d, {**tree, "c": jnp.zeros(2)})
        # dtype mismatch names the leaf
        with pytest.raises(ValueError, match="dtype mismatch at leaf 'a'"):
            load_checkpoint(d, {**tree, "a": tree["a"].astype(jnp.bfloat16)})
        # shape mismatch names the leaf
        with pytest.raises(ValueError, match="shape mismatch at leaf 'b'"):
            load_checkpoint(d, {**tree, "b": tree["b"].reshape(2, 2)})
        # truncated shard: rewrite the (single) shard without one leaf
        shard = next(Path(d).glob("shard_*.npz"))
        with np.load(shard) as z:
            kept = {k: z[k] for k in z.files if k != "b"}
        np.savez(shard, **kept)
        with pytest.raises(ValueError, match="absent from the shard"):
            load_checkpoint(d, tree)


def test_training_reduces_loss():
    """E2E sanity: 30 pjit-path steps on the synthetic corpus reduce loss."""
    cfg = get_config("qwen3-4b").reduced(n_layers=2, d_model=64, n_heads=4,
                                         vocab=128)
    dc = DataConfig(seq_len=64, global_batch=8)
    oc = OptConfig(lr=3e-3, warmup_steps=3, total_steps=30)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    stacked = {"embed": params["embed"],
               "blocks": M.stack_blocks(params["blocks"], M.period_of(cfg)),
               "head": params["head"]}
    opt = adamw_init(stacked)

    @jax.jit
    def step(p, o, batch):
        loss, g = jax.value_and_grad(
            lambda pp: M.loss_fn(pp, batch, cfg))(p)
        p2, o2, _ = adamw_update(p, g, o, oc)
        return p2, o2, loss

    losses = []
    for batch in make_batches(cfg, dc, 30):
        stacked, opt, loss = step(stacked, opt,
                                  {k: jnp.asarray(v)
                                   for k, v in batch.items()})
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.25, (losses[0], losses[-1])


def test_shardings_divisibility_fallback():
    """hubert's 504 vocab can't shard 16 ways -> replicated, not an error."""
    import os
    from jax.sharding import PartitionSpec as P
    # synthesize a fake mesh-shape object (no devices needed for specs)
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    from repro.launch.shardings import ShardOptions, param_specs
    cfg = get_config("hubert-xlarge")
    tree = {"head": {"w_lm": jnp.zeros((1280, 504))},
            "blocks": [{"mixer": {"wq": jnp.zeros((1280, 1280))}}]}
    specs = param_specs(tree, FakeMesh(), cfg, ShardOptions())
    assert specs["head"]["w_lm"] == P(None, None)          # 504 % 16 != 0
    assert specs["blocks"][0]["mixer"]["wq"] == P(None, "model")
