"""Paper Fig. 1: speedup of overlapping TP communication inside a
Transformer layer, naive vs braided execution, as TP size grows."""
from benchmarks.common import T_B, T_F, T_W, t_ar_for, write_csv


def main():
    rows = []
    for seq in (3072, 6144):
        for tp in (2, 4, 8):
            ar = t_ar_for(tp, 2, seq)
            naive_fwd = T_F + ar                   # AR exposed after compute
            braided_fwd = max(T_F, ar)             # hidden under partner B
            share = ar / naive_fwd
            rows.append([seq, tp, round(ar, 3), round(100 * share, 1),
                         round(naive_fwd / braided_fwd, 3)])
    write_csv("fig1_tp_overlap",
              ["seq", "tp", "t_ar", "tp_comm_share_%", "layer_speedup"],
              rows)


if __name__ == "__main__":
    main()
