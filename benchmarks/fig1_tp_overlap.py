"""Paper Fig. 1, *measured*: how much TP communication each schedule
exposes, naive vs braided composite execution.

For every schedule kind this builds the real SPMD pipeline step on a fake
``pp x tp`` CPU mesh three times —

  naive    — monolithic ``lax.psum`` at every unit boundary,
  braided  — ``braid_tp=True``: composite slots run
             ``chunk_fwd_bwd_braided`` with decomposed ring collectives
             interleaved against partner-chunk compute,
  no-tp    — ``ablate="tp"``: identical math executed with an identity
             ``TPContext`` (shard shapes preserved), isolating the
             TP-collective share of a step

— and reports per (kind, variant):

  * ``wall_s_per_step``        measured steady-state wall clock;
  * ``tp_comm_share``          (t_full - t_notp) / t_full, the measured
                               fraction of a step spent in TP collectives;
  * ``hlo.tp.exposed_share``   structural classification of every TP
                               collective in the compiled HLO as hidden
                               (independent matmul available inside its
                               dependence window) or exposed
                               (``launch.hlo_analysis.collective_overlap``);
  * ``tp_bubble_frac``         tp_comm_share x exposed_share — the measured
                               TP bubble: comm time with no compute to
                               hide it.

Fake-device caveat: all ranks share one CPU core, so *hidden* collectives
still cost wall clock here — overlap shows up in the structural HLO metric
and (on real accelerators) in wall clock, not in CPU wall clock.  The
decisive measured signal is ``hlo.tp.exposed_share`` braided < naive on
composite-slot schedules; the script prints a final

  overlap_check: PASS (braided <x> < naive <y>)

line aggregated over schedules that actually have composite slots (gpipe
has none — F and B never share a slot — so braiding is a structural no-op
there and it is excluded from the check).

The analytic A800-calibrated columns of the original figure (TP ring time
vs compute, layer speedup upper bound) are kept under ``analytic``.

Emits ``experiments/BENCH_tp_overlap.json``.

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python -m benchmarks.fig1_tp_overlap \
      [--pp 2] [--m 4] [--steps 4] [--kinds stp,zb-v]
"""
import argparse
import os

# The default XLA:CPU scheduler minimizes live memory, which keeps every
# dependence chain contiguous — a collective's consumer is placed right
# behind it and partner-chunk matmuls are hoisted out of its window, no
# matter what order the braid traced (opt-barriers are expanded before
# scheduling on CPU).  The concurrency-optimized scheduler is XLA's own
# model of an overlap-capable runtime and is what fig. 1 measures against.
_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_force_host_platform_device_count" not in _flags:
    _flags += " --xla_force_host_platform_device_count=4"
if "--xla_cpu_enable_concurrency_optimized_scheduler" not in _flags:
    _flags += " --xla_cpu_enable_concurrency_optimized_scheduler=true"
os.environ["XLA_FLAGS"] = _flags.strip()

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from benchmarks.common import T_F, t_ar_for, write_json
from benchmarks.bench_schedules import _time_fn
from repro.configs import get_config
from repro.core.schedule import SCHEDULES, build
from repro.launch.hlo_analysis import collective_overlap
from repro.models import model as M
from repro.pipeline import slots as SL
from repro.pipeline.spmd import build_pipeline_step, stack_stage_params


def analytic_rows():
    """The original Fig. 1 columns: per-layer AR share and the overlap
    speedup upper bound (T_F + ar) / max(T_F, ar) from the A800
    calibration."""
    rows = []
    for seq in (3072, 6144):
        for tp in (2, 4, 8):
            ar = t_ar_for(tp, 2, seq)
            rows.append({"seq": seq, "tp": tp, "t_ar": round(ar, 3),
                         "tp_comm_share": round(ar / (T_F + ar), 3),
                         "layer_speedup_bound":
                             round((T_F + ar) / max(T_F, ar), 3)})
    return rows


def main(pp: int = 2, m: int = 4, steps: int = 4, warmup: int = 1,
         kinds=None, d_model: int = 64, seq_len: int = 16):
    ndev = len(jax.devices())
    assert ndev % pp == 0, f"{ndev} devices not divisible by pp={pp}"
    tp = ndev // pp
    assert tp >= 2, f"need a model axis: {ndev} devices / pp={pp} -> tp={tp}"
    cfg = get_config("qwen3-4b").reduced(n_layers=2 * pp, d_model=d_model,
                                         n_heads=4, vocab=128)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    b = 2
    tokens = jax.random.randint(key, (m, b, seq_len), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.fold_in(key, 1),
                                (m, b, seq_len), 0, cfg.vocab)
    mesh = Mesh(np.array(jax.devices()).reshape(pp, tp), ("stage", "model"))

    results = {}
    for kind in kinds or SCHEDULES:
        tables, pl = build(kind, pp, m)
        codes = SL.encode(SL.to_slots(tables, pl), pl)
        n_comp = int(((codes[:, :, 0] > 0) & (codes[:, :, 2] > 0)).sum())
        c0, c1, _ = stack_stage_params(params, cfg, pp, kind=pl.kind)
        stacked = (c0, c1, params["embed"], params["head"])
        args = (*stacked, tokens, labels)

        variants, losses = {}, {}
        for name, kw in (("naive", {}), ("braided", {"braid_tp": True}),
                         ("notp", {"ablate": "tp"})):
            step = build_pipeline_step(cfg, tables, pl, mesh, m,
                                       (b, seq_len), stacked,
                                       model_axis="model", **kw)
            compiled = step.lower(*args).compile()
            with mesh:
                wall = _time_fn(step, args, steps=steps, warmup=warmup)
                losses[name] = float(jax.device_get(step(*args)[0]))
            variants[name] = {
                "wall_s_per_step": round(wall, 4),
                "hlo": {k: {kk: (round(vv, 4) if kk == "exposed_share"
                                 else vv) for kk, vv in v.items()}
                        for k, v in collective_overlap(
                            compiled.as_text(), tp_size=tp).items()},
            }
            print(f"[{kind:10s}] {name}: wall={wall:.3f}s "
                  f"tp_hlo={variants[name]['hlo']['tp']}", flush=True)
        t_notp = variants.pop("notp")["wall_s_per_step"]
        for v in variants.values():
            share = max(v["wall_s_per_step"] - t_notp, 0.0) \
                / v["wall_s_per_step"]
            v["tp_comm_share"] = round(share, 4)
            v["tp_bubble_frac"] = round(
                share * v["hlo"]["tp"]["exposed_share"], 4)
        loss_diff = abs(losses["braided"] - losses["naive"])
        assert loss_diff < 1e-5, (kind, losses)
        results[kind] = {
            "placement": pl.kind,
            "n_composite_slots": n_comp,
            "loss": round(losses["naive"], 4),
            "loss_diff_braided": loss_diff,
            "t_notp_s": t_notp,
            "naive": variants["naive"],
            "braided": variants["braided"],
        }
        print(f"[{kind:10s}] composite={n_comp} "
              f"naive bubble={results[kind]['naive']['tp_bubble_frac']} "
              f"braided bubble={results[kind]['braided']['tp_bubble_frac']}",
              flush=True)

    comp_kinds = [k for k, r in results.items() if r["n_composite_slots"]]
    agg = {
        name: round(float(np.mean(
            [results[k][name]["hlo"]["tp"]["exposed_share"]
             for k in comp_kinds])), 4)
        for name in ("naive", "braided")} if comp_kinds else {}
    write_json("BENCH_tp_overlap", {
        "setup": {"pp": pp, "tp": tp, "microbatches": m, "steps": steps,
                  "arch": cfg.name, "d_model": d_model, "seq_len": seq_len,
                  "devices": ndev,
                  "metric": "tp_bubble_frac = tp_comm_share (ablation) x "
                            "exposed_share (HLO structural)"},
        "schedules": results,
        "exposed_share_mean_composite": agg,
        "analytic": analytic_rows(),
    })
    if comp_kinds:
        verdict = "PASS" if agg["braided"] < agg["naive"] else "FAIL"
        print(f"overlap_check: {verdict} (braided {agg['braided']} "
              f"< naive {agg['naive']})", flush=True)
    else:
        print("overlap_check: SKIP (no composite slots in selected kinds)",
              flush=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--pp", type=int, default=2)
    ap.add_argument("--m", type=int, default=4)
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--d-model", type=int, default=64, dest="d_model")
    ap.add_argument("--seq-len", type=int, default=16, dest="seq_len")
    ap.add_argument("--kinds", type=lambda s: s.split(","), default=None,
                    help="comma-separated subset of schedules")
    args = ap.parse_args()
    main(**vars(args))
