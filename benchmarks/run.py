"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig9       # one
"""
import sys
import time

from benchmarks import (appA_warmup, fig7_fig8_llm, fig9_memory,
                        fig10_offload, roofline, table1_theory, table3_mllm,
                        table4_mfu)


def _fig1():
    # subprocess: fig1 measures on a pp=2 x tp=2 fake mesh and the device
    # count must be fixed before jax initializes
    import os
    import subprocess
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu")
    subprocess.run([sys.executable, "-m", "benchmarks.fig1_tp_overlap"],
                   check=True, env=env)


def _schedules():
    # subprocess: device count must be fixed before jax initializes
    import os
    import subprocess
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               JAX_PLATFORMS="cpu")
    subprocess.run([sys.executable, "-m", "benchmarks.bench_schedules"],
                   check=True, env=env)


def _table3():
    # subprocess: measured mode times the SPMD runtime on a pp=2 (x ep=2)
    # fake mesh, so the device count must be fixed before jax initializes
    import os
    import subprocess
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu")
    subprocess.run([sys.executable, "-m", "benchmarks.table3_mllm"],
                   check=True, env=env)


def _fig10():
    # subprocess: measured mode times naive-vs-offloaded SpmdRunner
    # programs on a pp=2 fake mesh, so the device count must be fixed
    # before jax initializes
    import os
    import subprocess
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               JAX_PLATFORMS="cpu")
    subprocess.run([sys.executable, "-m", "benchmarks.fig10_offload"],
                   check=True, env=env)


def _serve():
    # subprocess for the same reason; bench_serve pins its own XLA_FLAGS
    import os
    import subprocess
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    subprocess.run([sys.executable, "-m", "benchmarks.bench_serve",
                    "--smoke"], check=True, env=env)


ALL = {
    "table1": table1_theory.main,
    "fig1": _fig1,
    "fig7_fig8": fig7_fig8_llm.main,
    "table3": _table3,
    "table3_sim": table3_mllm.main_sim,
    "fig9": fig9_memory.main,
    "fig10": _fig10,
    "fig10_sim": fig10_offload.main_sim,
    "appA": appA_warmup.main,
    "table4": table4_mfu.main,
    "roofline": roofline.main,
    "schedules": _schedules,
    "serve": _serve,
}


def main():
    picks = [a for a in sys.argv[1:] if not a.startswith("-")]
    unknown = [n for n in picks if n not in ALL]
    if unknown:
        print(f"unknown benchmark(s): {', '.join(unknown)}",
              file=sys.stderr)
        print(f"available: {', '.join(ALL)}", file=sys.stderr)
        sys.exit(1)
    names = picks or list(ALL)
    for name in names:
        t0 = time.time()
        ALL[name]()
        print(f"[{name}] done in {time.time() - t0:.1f}s\n", flush=True)


if __name__ == "__main__":
    main()
