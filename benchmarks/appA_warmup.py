"""Paper App. A/B: memory-efficient warm-up (schedule d) vs the standard
schedule (c) — lower peak memory, extra tail bubbles."""
from repro.core.schedule import run as run_schedule

from benchmarks.common import times_for, write_csv


def main():
    rows = []
    for pp, m in [(2, 32), (4, 48)]:
        times = times_for(8 if pp == 2 else 4, pp, 6144)
        for kind, label in [("stp", "ours (c)"),
                            ("stp-memeff", "ours (d) mem-eff warmup")]:
            res, _, _ = run_schedule(kind, pp, m, times)
            s = res.summary()
            rows.append([pp, m, label, round(s["total_time"], 1),
                         round(s["pp_bubble_mean"], 1),
                         round(s["peak_mem_max"], 1)])
    write_csv("appA_warmup",
              ["pp", "m", "schedule", "total_time", "pp_bubble",
               "peak_mem_Ma"], rows)


if __name__ == "__main__":
    main()
