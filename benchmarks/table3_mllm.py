"""Paper Table 3: MLLM training throughput with an imbalanced ViT first
virtual stage.  PP=4 is workload-balanced (ViT FLOPs ~ one virtual stage);
PP=2 has a lighter ViT, PP=8 a heavier one (the paper's three regimes)."""
from repro.core.schedule import run as run_schedule

from benchmarks.common import times_for, write_csv

# (model, tp, pp, vit_factor): Table 3 rows at the largest mbs.
PAPER = {
    ("14.9B", 4, 4, 1.0): {"mbs": 192, "1f1b-i": 4.46, "zb-v": 4.31,
                           "stp": 4.65},
    ("14.9B", 8, 2, 0.6): {"mbs": 192, "1f1b-i": 2.46, "zb-v": 2.49,
                           "stp": 2.87},
    ("28.8B", 4, 8, 1.6): {"mbs": 256, "1f1b-i": 5.85, "zb-v": 6.01,
                           "stp": 6.19},
}


def main():
    rows = []
    for (model, tp, pp, vit), paper in PAPER.items():
        times = times_for(tp, pp, 5120, t_comm=0.05, vit_factor=vit)
        sim = {}
        for kind in ("1f1b-i", "zb-v", "stp"):
            res, _, _ = run_schedule(kind, pp, paper["mbs"], times)
            sim[kind] = paper["mbs"] / res.total_time
        scale = paper["1f1b-i"] / sim["1f1b-i"]
        for kind in ("1f1b-i", "zb-v", "stp"):
            pred = sim[kind] * scale
            rows.append([model, tp, pp, vit, kind, round(pred, 2),
                         paper[kind],
                         f"{100 * (pred / paper[kind] - 1):+.1f}%"])
        gp = sim["stp"] / sim["1f1b-i"] - 1
        gm = paper["stp"] / paper["1f1b-i"] - 1
        rows.append([model, tp, pp, vit, "stp_gain", f"{100 * gp:.1f}%",
                     f"{100 * gm:.1f}%", ""])
    write_csv("table3_mllm",
              ["model", "tp", "pp", "vit_factor", "schedule", "sim",
               "paper", "rel_err"], rows)


if __name__ == "__main__":
    main()
