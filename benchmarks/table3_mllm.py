"""Paper Table 3: MLLM training with an imbalanced ViT-heavy first stage.

Default (measured) mode — runs the real SPMD runtime on a fake CPU mesh
and times cost-balanced per-stage partitions against the naive baseline
for 1f1b-i / zb-v / stp, plus EP=2 vs EP=1 on the MoE arch, emitting
``experiments/BENCH_table3.json``.  Three arms per schedule:

  uniform-pad — what the seed executor required: the 10-layer ViT-heavy
                model padded to 12 layers so ``n_layers % n_vs == 0``,
                split 3/3/3/3 (the pad layers burn real FLOPs);
  uniform     — partition-generic executor, cost-blind near-uniform split
                of the true 10 layers (3/3/2/2);
  balanced    — ``core.schedule.partition``'s cost-balanced split (the
                heavy ViT-encoder front sheds layers off stage 0).

Fake-device caveat (ROADMAP): every fake device shares one CPU core, so
wall-clock measures total executed work, not idle silicon — the padding
elimination (balanced/uniform vs uniform-pad) is the honestly measurable
win here, while uniform vs balanced is a FLOPs tie whose bubble-level gap
only the simulator can rank.  ``--sim`` keeps the original
simulator-vs-paper CSV (Table 3 numbers).

  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      python -m benchmarks.table3_mllm [--sim] [--steps N] [--repeats R]
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

# (model, tp, pp, vit_factor): Table 3 rows at the largest mbs.
PAPER = {
    ("14.9B", 4, 4, 1.0): {"mbs": 192, "1f1b-i": 4.46, "zb-v": 4.31,
                           "stp": 4.65},
    ("14.9B", 8, 2, 0.6): {"mbs": 192, "1f1b-i": 2.46, "zb-v": 2.49,
                           "stp": 2.87},
    ("28.8B", 4, 8, 1.6): {"mbs": 256, "1f1b-i": 5.85, "zb-v": 6.01,
                           "stp": 6.19},
}

KINDS = ("1f1b-i", "zb-v", "stp")


def main_sim():
    """Original simulator-vs-paper CSV (Table 3 throughput numbers)."""
    from repro.core.schedule import run as run_schedule

    from benchmarks.common import times_for, write_csv

    rows = []
    for (model, tp, pp, vit), paper in PAPER.items():
        times = times_for(tp, pp, 5120, t_comm=0.05, vit_factor=vit)
        sim = {}
        for kind in KINDS:
            res, _, _ = run_schedule(kind, pp, paper["mbs"], times)
            sim[kind] = paper["mbs"] / res.total_time
        scale = paper["1f1b-i"] / sim["1f1b-i"]
        for kind in KINDS:
            pred = sim[kind] * scale
            rows.append([model, tp, pp, vit, kind, round(pred, 2),
                         paper[kind],
                         f"{100 * (pred / paper[kind] - 1):+.1f}%"])
        gp = sim["stp"] / sim["1f1b-i"] - 1
        gm = paper["stp"] / paper["1f1b-i"] - 1
        rows.append([model, tp, pp, vit, "stp_gain", f"{100 * gp:.1f}%",
                     f"{100 * gm:.1f}%", ""])
    write_csv("table3_mllm",
              ["model", "tp", "pp", "vit_factor", "schedule", "sim",
               "paper", "rel_err"], rows)


VIT_FACTOR = 3.0  # stage-0 cost multiplier modeling the resident ViT


def _vit_heavy(extra: int = 0):
    """ViT-heavy MLLM stand-in: 10 identical decoder layers behind a
    resident ViT encoder co-located on virtual stage 0, modeled by
    ``vit_factor=VIT_FACTOR`` in the balanced arm (stage 0's layers cost
    3x, so ``partition`` sheds layers off it — Table 3's imbalance).  10
    layers over n_vs=4 is deliberately ragged; ``extra`` pad layers model
    the seed executor's forced round-up to a multiple of n_vs."""
    from repro.models.config import LayerSpec, ModelConfig
    return ModelConfig(
        name=f"vit-heavy-{10 + extra}l", family="vlm", d_model=64,
        n_heads=4, kv_heads=4, d_ff=128, vocab=128,
        layers=(LayerSpec(mixer="attn", mlp="gated"),) * (10 + extra),
        max_seq=4096)


def _time(runner, params, batches, warmup, repeats):
    from benchmarks.common import time_runner
    state = runner.init_state(params)
    best = None
    for _ in range(repeats):
        s, state, _ = time_runner(runner, state, batches, warmup=warmup)
        best = s if best is None else min(best, s)
    return best


def main_measured(steps: int = 3, warmup: int = 1, repeats: int = 2):
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from benchmarks.common import write_json
    from repro.configs import get_config
    from repro.core.schedule import uniform_ranges
    from repro.data import DataConfig, make_batches
    from repro.launch.runner import make_runner
    from repro.models import model as M
    from repro.optim import OptConfig

    dc = DataConfig(global_batch=4, microbatches=4, seq_len=32)
    oc = OptConfig()
    mesh2 = Mesh(np.array(jax.devices()[:2]).reshape(2, 1),
                 ("stage", "model"))

    cfg10, cfg12 = _vit_heavy(), _vit_heavy(2)
    arms = {
        "uniform-pad": (cfg12, uniform_ranges(12, 4), 1.0),
        "uniform": (cfg10, uniform_ranges(10, 4), 1.0),
        # cost-balanced via partition() under the ViT stage-0 weighting
        "balanced": (cfg10, None, VIT_FACTOR),
    }
    params = {c.name: M.init_params(jax.random.PRNGKey(0), c)
              for c in (cfg10, cfg12)}
    batches = list(make_batches(cfg10, dc, warmup + steps))

    part_res = {}
    for kind in KINDS:
        part_res[kind] = {}
        for tag, (cfg, part, vf) in arms.items():
            r = make_runner("spmd", cfg, oc, dc, schedule=kind, pp=2,
                            tp=1, mesh=mesh2, part=part, vit_factor=vf)
            s = _time(r, params[cfg.name], batches, warmup, repeats)
            part_res[kind][tag] = {
                "s_per_step": round(s, 4), "n_layers": cfg.n_layers,
                "part": [b - a for a, b in r.part]}
            print(f"[table3] {kind:8s} {tag:12s} "
                  f"part={part_res[kind][tag]['part']} {s:.3f} s/step",
                  flush=True)
        pr = part_res[kind]
        pr["speedup_vs_uniform_pad"] = round(
            pr["uniform-pad"]["s_per_step"] / pr["balanced"]["s_per_step"],
            3)

    # EP=2 vs EP=1 on the seeded MoE arch (pp=2 x ep=2 on 4 fake devices).
    cfg_moe = get_config("olmoe-1b-7b").reduced(n_layers=2, d_model=64,
                                                n_heads=4, vocab=128)
    pm = M.init_params(jax.random.PRNGKey(1), cfg_moe)
    bm = list(make_batches(cfg_moe, dc, warmup + steps))
    ep_res = {"arch": cfg_moe.name, "num_experts": cfg_moe.moe.num_experts}
    for ep, mesh in ((1, mesh2), (2, None)):
        r = make_runner("spmd", cfg_moe, oc, dc, schedule="1f1b", pp=2,
                        tp=1, ep=ep, mesh=mesh)
        s = _time(r, pm, bm, warmup, repeats)
        ep_res[f"ep{ep}_s_per_step"] = round(s, 4)
        print(f"[table3] moe ep={ep} {s:.3f} s/step", flush=True)
    ep_res["note"] = ("shared-core fake devices: ep=2 halves per-device "
                      "expert FLOPs/weights but total work is constant, so "
                      "parity (not speedup) is the expected wall-clock")

    balanced_faster = all(
        part_res[k]["balanced"]["s_per_step"]
        < part_res[k]["uniform-pad"]["s_per_step"] for k in KINDS)
    write_json("BENCH_table3", {
        "setting": {
            "devices": len(jax.devices()), "pp": 2,
            "microbatches": dc.microbatches, "seq_len": dc.seq_len,
            "steps": steps, "warmup": warmup, "repeats": repeats,
            "vit_factor": VIT_FACTOR,
            "caveat": ("one shared CPU core: wall-clock ranks total "
                       "executed work; padding elimination is the "
                       "measurable win, bubble-level uniform-vs-balanced "
                       "gaps are simulator territory (--sim)")},
        "partition": part_res,
        "balanced_strictly_faster_than_uniform_pad": balanced_faster,
        "expert_parallel": ep_res,
    })
    if not balanced_faster:
        raise SystemExit("cost-balanced partition not faster than "
                         "uniform-pad baseline")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sim", action="store_true",
                    help="simulator-vs-paper CSV instead of measured mode")
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--repeats", type=int, default=2)
    args = ap.parse_args()
    if args.sim:
        main_sim()
    else:
        main_measured(steps=args.steps, warmup=args.warmup,
                      repeats=args.repeats)


if __name__ == "__main__":
    main()
