"""Paper Fig. 9 / Table 5: peak activation memory per device.  Calibration:
one microbatch-chunk activation M_a from the 12.1B model at seq 6144
(paper profiles ~3.6 GB/chunk for ZB-V at TP=8)."""
from repro.core.schedule import run as run_schedule

from benchmarks.common import times_for, write_csv

# per-chunk per-microbatch activation M_a (GB), 12.1B @ 6144, fitted to the
# paper's Table 5 profile (TP=4 shards activations across 4 ranks but holds
# 2x the layers per chunk vs TP=8/PP=2 -> ~4.1 GB; TP=8/PP=2 ~7.1 GB).
MA_GB = {(4, 4): 4.1, (8, 2): 7.1}

# paper Table 5 (12.1B, 6144)
PAPER = {(4, 4): {"1f1b-i": 41, "zb-v": 30, "stp": 54},
         (8, 2): {"1f1b-i": 31, "zb-v": 24, "stp": 43}}


def main():
    rows = []
    for (tp, pp), paper in PAPER.items():
        times = times_for(tp, pp, 6144)
        for kind in ("1f1b-i", "zb-v", "stp"):
            res, _, _ = run_schedule(kind, pp, 64, times)
            sim_gb = [round(x * MA_GB[(tp, pp)], 1) for x in res.peak_mem]
            rows.append([tp, pp, kind, max(sim_gb), paper[kind],
                         " ".join(map(str, sim_gb))])
    write_csv("fig9_memory",
              ["tp", "pp", "schedule", "peak_gb_sim", "peak_gb_paper",
               "per_device_gb"], rows)


if __name__ == "__main__":
    main()
