"""Paper Figs. 7-8 / Table 6: LLM training throughput (samples/s) across
schedules, (TP, PP), sequence lengths and microbatch counts.

The simulator's time unit is calibrated per configuration so 1F1B-I matches
the paper's measured samples/s at mbs=192(/256); STP and ZB-V throughputs
are then *predictions* compared against the paper's measurements.
"""
from repro.core.schedule import run as run_schedule

from benchmarks.common import times_for, write_csv

# Paper Table 6 (samples/s), keyed (model, seq, tp, pp) -> {sched: [mbs...]}
PAPER = {
    ("12.1B", 3072, 4, 4): {"mbs": [64, 128, 192],
                            "1f1b-i": [9.52, 9.63, 9.66],
                            "zb-v": [9.12, 9.26, 9.31],
                            "stp": [9.87, 10.1, 10.1]},
    ("12.1B", 3072, 8, 2): {"mbs": [64, 128, 192],
                            "1f1b-i": [6.57, 6.60, 6.60],
                            "zb-v": [6.42, 6.46, 6.46],
                            "stp": [7.28, 7.32, 7.33]},
    ("12.1B", 6144, 4, 4): {"mbs": [64, 128, 192],
                            "1f1b-i": [4.51, 4.57, 4.58],
                            "zb-v": [4.45, 4.48, 4.49],
                            "stp": [4.74, 4.82, 4.83]},
    ("12.1B", 6144, 8, 2): {"mbs": [64, 128, 192],
                            "1f1b-i": [3.11, 3.13, 3.11],
                            "zb-v": [3.13, 3.13, 3.13],
                            "stp": [3.46, 3.47, 3.49]},
    ("26.3B", 2048, 4, 8): {"mbs": [96, 176, 256],
                            "1f1b-i": [12.3, 12.8, 12.7],
                            "zb-v": [12.4, 12.7, 12.8],
                            "stp": [13.0, 13.2, 13.4]},
    ("26.3B", 2048, 8, 4): {"mbs": [96, 176, 256],
                            "1f1b-i": [8.60, 8.67, 8.68],
                            "zb-v": [8.71, 8.79, 8.79],
                            "stp": [9.48, 9.56, 9.61]},
    ("26.3B", 4096, 4, 8): {"mbs": [96, 176, 256],
                            "1f1b-i": [6.16, 6.17, 6.28],
                            "zb-v": [6.17, 6.28, 6.31],
                            "stp": [6.33, 6.49, 6.51]},
    ("26.3B", 4096, 8, 4): {"mbs": [96, 176, 256],
                            "1f1b-i": [4.23, 4.24, 4.25],
                            "zb-v": [4.26, 4.28, 4.29],
                            "stp": [4.66, 4.70, 4.72]},
}


def simulate_config(seq, tp, pp, mbs_list, t_comm=0.05):
    out = {}
    times = times_for(tp, pp, seq, t_comm=t_comm)
    for kind in ("1f1b-i", "zb-v", "stp"):
        out[kind] = []
        for m in mbs_list:
            res, _, _ = run_schedule(kind, pp, m, times)
            out[kind].append(m / res.total_time)   # samples per time unit
    return out


def main():
    rows = []
    worst = 0.0
    for (model, seq, tp, pp), paper in PAPER.items():
        sim = simulate_config(seq, tp, pp, paper["mbs"])
        # calibrate time unit on 1F1B-I at the largest mbs
        scale = paper["1f1b-i"][-1] / sim["1f1b-i"][-1]
        for kind in ("1f1b-i", "zb-v", "stp"):
            for i, m in enumerate(paper["mbs"]):
                pred = sim[kind][i] * scale
                meas = paper[kind][i]
                err = pred / meas - 1
                if kind != "1f1b-i" or i != len(paper["mbs"]) - 1:
                    worst = max(worst, abs(err))
                rows.append([model, seq, tp, pp, kind, m,
                             round(pred, 2), meas, f"{100 * err:+.1f}%"])
        gain_pred = sim["stp"][-1] / sim["1f1b-i"][-1] - 1
        gain_meas = paper["stp"][-1] / paper["1f1b-i"][-1] - 1
        rows.append([model, seq, tp, pp, "stp_gain_vs_1f1bi", "-",
                     f"{100 * gain_pred:.1f}%", f"{100 * gain_meas:.1f}%",
                     ""])
    write_csv("fig7_fig8_llm",
              ["model", "seq", "tp", "pp", "schedule", "mbs",
               "samples_per_s_sim", "samples_per_s_paper", "rel_err"],
              rows)
    print(f"worst prediction error vs paper: {100 * worst:.1f}%")


if __name__ == "__main__":
    main()
