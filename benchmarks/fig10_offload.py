"""Paper Fig. 10 / §5.4: the enhanced (offloading) variant — peak memory
reduction 10-19.2% at negligible throughput cost, memory balanced across
stages."""
from repro.core.schedule import build as build_schedule
from repro.core.simulator import simulate

from benchmarks.common import times_for, write_csv


def main():
    rows = []
    pp, tp, m = 4, 4, 64
    times = times_for(tp, pp, 6144)
    tables, pl = build_schedule("stp", pp, m, times)
    base = simulate(tables, pl, times, m)
    for alpha in (0.0, 0.2, 0.4, 0.6):
        off = simulate(tables, pl, times, m, offload_alpha=alpha,
                       offload_overhead=0.02 if alpha else 0.0)
        red = 1 - off.peak_mem.max() / base.peak_mem.max()
        thr = base.total_time / off.total_time
        imb = off.peak_mem.max() - off.peak_mem.min()
        rows.append([alpha, round(float(off.peak_mem.max()), 2),
                     f"{100 * red:.1f}%", round(thr, 4),
                     round(float(imb), 2)])
    write_csv("fig10_offload",
              ["alpha", "peak_mem_Ma", "reduction", "rel_throughput",
               "imbalance_Ma"], rows)


if __name__ == "__main__":
    main()
