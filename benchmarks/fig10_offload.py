"""Paper Fig. 10 / §5.4: the enhanced (offloading) variant.

Measured mode (default) drives the *real* SPMD runtime: for ``stp`` and
``stp-memeff`` it builds the fused train step twice — naive (α=0) and
offloaded (``--alpha``, default 0.4) — on a pp=2 fake-CPU mesh and reports

  * peak live activation bytes of the lowered program's carry buffers
    (``SpmdRunner.act_stats``: per-microbatch chunk contexts, the
    double-buffered FETCH staging rows, head context and W-tape), split
    device vs host side;
  * measured wall-clock s/step (best-of-``--repeats`` mean over steady
    steps, repeats interleaved round-robin across configs so CPU clock
    drift cannot bias one config);
  * the train loss of both variants — the offloaded program must match the
    naive one bitwise (the α split/join is pure data movement).

A final grep-able ``offload_check: PASS|FAIL`` line asserts the paper's
claim at bench scale: device-side activation bytes drop ≥ ``--min-reduction``
(default 10%) at ≤ ``--max-slowdown`` (default 5%) s/step cost.  Emits
``experiments/BENCH_fig10.json``.  Fake-device caveat: all stages share one
CPU, so the s/step cost bound is the honest signal, not absolute speed.

``--sim`` (or ``benchmarks.run fig10_sim``) keeps the simulator sweep that
reproduces the paper numbers — peak memory reduction 10–19.2% at negligible
throughput cost on the memory-efficient STP schedule — as a CSV.

  XLA_FLAGS=--xla_force_host_platform_device_count=2 \
  PYTHONPATH=src python -m benchmarks.fig10_offload [--m 8] [--alpha 0.4]
"""
import argparse
import os
import sys

from benchmarks.common import times_for, write_csv, write_json


def main_sim():
    from repro.core.schedule import build as build_schedule
    from repro.core.simulator import simulate

    rows = []
    pp, tp, m = 4, 4, 64
    times = times_for(tp, pp, 6144)
    # §5.4's enhanced variant offloads on the *memory-efficient* STP
    # schedule (stp-memeff), not plain stp.
    tables, pl = build_schedule("stp-memeff", pp, m, times)
    base = simulate(tables, pl, times, m)
    for alpha in (0.0, 0.2, 0.4, 0.6):
        off = simulate(tables, pl, times, m, offload_alpha=alpha,
                       offload_overhead=0.02 if alpha else 0.0)
        red = 1 - off.peak_mem.max() / base.peak_mem.max()
        thr = base.total_time / off.total_time
        imb = off.peak_mem.max() - off.peak_mem.min()
        rows.append([alpha, round(float(off.peak_mem.max()), 2),
                     f"{100 * red:.1f}%", round(thr, 4),
                     round(float(imb), 2)])
    write_csv("fig10_offload",
              ["alpha", "peak_mem_Ma", "reduction", "rel_throughput",
               "imbalance_Ma"], rows)


def main(pp: int = 2, m: int = 8, alpha: float = 0.4, steps: int = 5,
         warmup: int = 1, repeats: int = 3, d_model: int = 128,
         seq_len: int = 64, kinds=None, min_reduction: float = 0.10,
         max_slowdown: float = 0.05, xla_memory: bool = False):
    import jax
    import jax.numpy as jnp

    from benchmarks.common import time_runner
    from repro.api import make_runner
    from repro.configs import get_config
    from repro.data import DataConfig, make_batches
    from repro.models import model as M
    from repro.optim import OptConfig

    kinds = kinds or ("stp", "stp-memeff")
    ndev = len(jax.devices())
    assert ndev % pp == 0, f"{ndev} devices not divisible by pp={pp}"
    tp = ndev // pp
    cfg = get_config("qwen3-4b").reduced(n_layers=4, d_model=d_model,
                                         n_heads=4, vocab=128)
    oc = OptConfig(lr=1e-3, warmup_steps=2, total_steps=steps)
    dc = DataConfig(seq_len=seq_len, global_batch=2 * m, microbatches=m)
    batches = [{k: jnp.asarray(v) for k, v in raw.items()}
               for raw in make_batches(cfg, dc, steps)]
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    # Phase 1 — build + compile every (kind, alpha) program.
    prog, results = {}, {}
    for kind in kinds:
        results[kind] = {}
        for a in (0.0, alpha):
            runner = make_runner("spmd", cfg, oc, dc, schedule=kind, pp=pp,
                                 tp=tp, offload_alpha=a)
            state = runner.init_state(params)
            state, metrics = runner.step(state, batches[0])   # compile
            prog[(kind, a)] = (runner, state)
            st = runner.act_stats
            row = {
                "device_act_bytes": st["device_act_bytes"],
                "host_act_bytes": st["host_act_bytes"],
                "tape_bytes": st["tape_bytes"],
                "device_total_bytes": st["device_total_bytes"],
                "loss": float(metrics["loss"]),
            }
            if xla_memory:
                # XLA's own view of the compiled program (includes weights
                # and optimizer temps, so it is looser than act bytes).
                try:
                    from repro.data import microbatches
                    mbs = microbatches(batches[0], m)
                    tokens = jnp.stack([b["tokens"] for b in mbs])
                    labels = jnp.stack([b["labels"] for b in mbs])
                    with runner.mesh:
                        ma = runner._step.lower(
                            state.params, state.opt, tokens,
                            labels).compile().memory_analysis()
                    row["xla_temp_bytes"] = int(ma.temp_size_in_bytes)
                except Exception as e:          # backend-dependent API
                    row["xla_temp_bytes_error"] = repr(e)
            results[kind][f"alpha={a:g}"] = row
            print(f"[{kind:10s} a={a:g}] compiled "
                  f"device_act={row['device_act_bytes']:,} "
                  f"host_act={row['host_act_bytes']:,}", flush=True)

    # Phase 2 — interleaved timing, best-of-repeats per program.
    walls = {}
    for rep in range(repeats):
        for key, (runner, state) in prog.items():
            w, state, _ = time_runner(runner, state, batches, warmup=warmup)
            prog[key] = (runner, state)
            walls[key] = w if key not in walls else min(walls[key], w)
        print(f"[round {rep + 1}/{repeats}] "
              + " ".join(f"{k}@{a:g}={walls[(k, a)]:.3f}"
                         for k, a in walls), flush=True)

    ok = True
    for kind in kinds:
        base = results[kind]["alpha=0"]
        off = results[kind][f"alpha={alpha:g}"]
        base["wall_s_per_step"] = round(walls[(kind, 0.0)], 4)
        off["wall_s_per_step"] = round(walls[(kind, alpha)], 4)
        red = 1 - off["device_act_bytes"] / base["device_act_bytes"]
        slow = walls[(kind, alpha)] / walls[(kind, 0.0)] - 1
        ldiff = abs(off["loss"] - base["loss"])
        results[kind]["reduction_frac"] = round(red, 4)
        results[kind]["slowdown_frac"] = round(slow, 4)
        results[kind]["loss_diff"] = ldiff
        kind_ok = (red >= min_reduction and slow <= max_slowdown
                   and ldiff < 1e-5)
        results[kind]["pass"] = kind_ok
        ok = ok and kind_ok
        print(f"[{kind:10s}] act bytes -{100 * red:.1f}% "
              f"s/step {'+' if slow >= 0 else ''}{100 * slow:.1f}% "
              f"loss_diff={ldiff:.2e}", flush=True)

    write_json("BENCH_fig10", {
        "setup": {"pp": pp, "tp": tp, "microbatches": m, "alpha": alpha,
                  "steps": steps, "repeats": repeats, "arch": cfg.name,
                  "d_model": d_model, "seq_len": seq_len, "devices": ndev,
                  "runner": "SpmdRunner (fused in-mesh AdamW)",
                  "min_reduction": min_reduction,
                  "max_slowdown": max_slowdown},
        "kinds": results,
    })
    print(f"offload_check: {'PASS' if ok else 'FAIL'} "
          f"(reduction >= {min_reduction:.0%}, "
          f"slowdown <= {max_slowdown:.0%}, loss bitwise)", flush=True)
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=2")
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--sim", action="store_true",
                    help="simulator sweep (paper CSV) instead of measuring")
    ap.add_argument("--pp", type=int, default=2)
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=0.4)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--d-model", type=int, default=128, dest="d_model")
    ap.add_argument("--seq-len", type=int, default=64, dest="seq_len")
    ap.add_argument("--kinds", type=lambda s: tuple(s.split(",")),
                    default=None,
                    help="comma-separated subset of {stp,stp-memeff}")
    ap.add_argument("--min-reduction", type=float, default=0.10,
                    dest="min_reduction")
    ap.add_argument("--max-slowdown", type=float, default=0.05,
                    dest="max_slowdown",
                    help="s/step budget for the offloaded variant (CI may "
                         "pass a looser bound: fake-device timing is noisy)")
    ap.add_argument("--xla-memory", action="store_true", dest="xla_memory",
                    help="also record XLA temp_size via memory_analysis() "
                         "(recompiles each program)")
    args = vars(ap.parse_args())
    main_sim() if args.pop("sim") else main(**args)
