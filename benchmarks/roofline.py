"""§Roofline aggregation: read experiments/dryrun/*.json (produced by
repro.launch.dryrun) into the per-(arch x shape x mesh) roofline table."""
import json
from pathlib import Path

from benchmarks.common import OUT, write_csv


def main():
    d = OUT / "dryrun"
    rows = []
    for f in sorted(d.glob("*.json")) if d.exists() else []:
        r = json.loads(f.read_text())
        tag = f.stem.split("_")[-1]
        if "skipped" in r:
            rows.append([r["arch"], r["shape"], tag, "SKIP", r["skipped"],
                         "", "", "", "", "", ""])
            continue
        if "error" in r:
            rows.append([r["arch"], r["shape"], tag, "FAIL",
                         r["error"][:60], "", "", "", "", "", ""])
            continue
        rl = r["roofline"]
        mem = r.get("memory_analysis") or {}
        rows.append([
            r["arch"], r["shape"], tag, "OK", rl["dominant"],
            f"{rl['compute_s']:.4f}", f"{rl['memory_s']:.4f}",
            f"{rl['collective_s']:.4f}",
            f"{(rl['useful_flops_frac'] or 0):.3f}",
            f"{(mem.get('peak_bytes') or 0) / 2**30:.2f}",
            f"{r['per_chip']['collective_bytes'] / 2**30:.2f}",
        ])
    write_csv("roofline",
              ["arch", "shape", "mesh", "status", "dominant/why",
               "compute_s", "memory_s", "collective_s", "useful_flops",
               "peak_gb_per_chip", "coll_gb_per_chip"], rows)


if __name__ == "__main__":
    main()
