"""Paper Table 4 / §5.5: throughput + MFU under maximized memory use (H20).
MFU here = ideal compute time / simulated iteration time; the H20's lower
TP-comm share is modelled by shrinking T_AR (App. D: comm proportion is
'significantly lower' on H20 than A800 — we use 45%)."""
from repro.core.schedule import run as run_schedule
from repro.core.simulator import StageTimes
from repro.core.theory import ideal_time, UnitTimes

from benchmarks.common import T_B, T_F, T_W, t_ar_for, write_csv

H20_AR_SCALE = 0.45

PAPER = {  # (tp, pp) -> measured MFU % at mbs=192, seq 8192
    (2, 8): {"1f1b-i": 92.09, "zb-v": 88.36, "stp": 92.86},
    (4, 4): {"1f1b-i": 83.62, "zb-v": 81.59, "stp": 85.32},
    (8, 2): {"1f1b-i": 69.74, "zb-v": 70.08, "stp": 71.78},
}


def main():
    rows = []
    m = 192
    for (tp, pp), paper in PAPER.items():
        ar = t_ar_for(tp, pp, 8192) * H20_AR_SCALE
        u = UnitTimes(t_f=T_F, t_b=T_B, t_w=T_W, t_ar=ar)
        times = StageTimes.uniform(2 * pp, t_f=T_F, t_b=T_B, t_w=T_W,
                                   t_ar=ar, m_a=1.0, t_comm=0.05)
        ideal = ideal_time(pp, m, u)
        for kind in ("1f1b-i", "zb-v", "stp"):
            res, _, _ = run_schedule(kind, pp, m, times)
            # scheduling efficiency; scaled into the paper's MFU band by
            # the per-config kernel efficiency implied by 1F1B-I's MFU
            eff = ideal / res.total_time
            rows.append([tp, pp, kind, f"{100 * eff:.2f}", paper[kind]])
    write_csv("table4_mfu",
              ["tp", "pp", "schedule", "sched_efficiency_%",
               "paper_mfu_%"], rows)


if __name__ == "__main__":
    main()
