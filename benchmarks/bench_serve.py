"""Serving benchmark: Poisson open-loop load against the continuous-batching
engine, three architecture families, TP-sharded on fake CPU devices.

Per family (attention / sliding-window / SSM):

  1. **Differential gate** — ``Engine.generate`` greedy outputs must match
     the token-at-a-time reference oracle exactly (same tokens, every
     request); a serving engine that returns different tokens has no
     business being benchmarked.
  2. **Poisson load** — requests arrive by an open-loop exponential clock
     (fixed seed), the engine admits/batches/retires them tick by tick, and
     we report decode throughput, TTFT and end-to-end latency percentiles,
     plus queue/occupancy maxima from the engine's own metrics.

Fake-device caveat: both TP ranks share one CPU core, so absolute tok/s is
meaningless; the comparable signal is that all families serve under the
same engine with sane queueing behaviour.  Emits
``experiments/BENCH_serve.json``.

  PYTHONPATH=src python -m benchmarks.bench_serve [--smoke] [--requests 12] \
      [--rate 20] [--gen 8] [--tp 2]
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from pathlib import Path


def _parse():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs + small load (CI-sized)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="mean arrival rate, requests/second")
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--tp", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args()


ARGS = _parse()
# Device count must be pinned before jax initializes.
os.environ.setdefault(
    "XLA_FLAGS", f"--xla_force_host_platform_device_count={ARGS.tp}")
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax                                                    # noqa: E402
import numpy as np                                            # noqa: E402

from benchmarks.common import write_json                      # noqa: E402
from repro.configs import get_config                          # noqa: E402
from repro.models import model as M                           # noqa: E402
from repro.serve import (Engine, EngineConfig, reference,     # noqa: E402
                         stacked_params)

FAMILIES = [("attention", "qwen3-4b"),
            ("sliding-window", "gemma3-12b"),
            ("ssm", "xlstm-125m")]


def _engine(cfg, params, args):
    return Engine(cfg, params, EngineConfig(
        tp=args.tp, data=1, rows=4, blocks=48, block_size=8,
        max_seq=96, max_queue=64, prefill_group=2, prefill_bucket=8))


def _differential(cfg, params, eng, args, rng):
    plen = 16
    prompts = rng.integers(0, cfg.vocab,
                           size=(4, plen)).astype(np.int32)
    st = stacked_params(cfg, params)
    ref = np.asarray(reference.generate(cfg, st, prompts, args.gen,
                                        max_seq=plen + args.gen + 1))
    outs = eng.generate(list(prompts), args.gen)
    return all(np.array_equal(outs[i], ref[i]) for i in range(len(outs)))


def _poisson_load(cfg, eng, args, rng):
    """Open-loop Poisson arrivals: submission times are drawn up front from
    an exponential clock; the driver submits whatever has 'arrived' by
    wall-clock each tick and steps the engine until everything drains."""
    n = args.requests
    gaps = rng.exponential(1.0 / args.rate, size=n)
    arrivals = np.cumsum(gaps)
    plens = rng.choice([8, 16], size=n)
    prompts = [rng.integers(0, cfg.vocab, size=p).astype(np.int32)
               for p in plens]

    # Warmup both prompt-length buckets + decode, then reset the clock.
    for p in (8, 16):
        eng.generate([np.zeros(p, np.int32)], 2)
    eng.reset_metrics()

    t0 = time.perf_counter()
    nxt = 0
    completed = 0
    while completed < n - eng.metrics.rejected:
        now = time.perf_counter() - t0
        while nxt < len(arrivals) and arrivals[nxt] <= now:
            eng.submit(prompts[nxt], args.gen)
            nxt += 1
        if eng.scheduler.depth or eng.pool.active_rows:
            completed += len(eng.step())
        elif nxt < len(arrivals):
            time.sleep(min(0.002, arrivals[nxt] - now))
    return eng.metrics.summary()


def main():
    args = ARGS
    rng = np.random.default_rng(args.seed)
    results = {"tp": args.tp, "requests": args.requests, "rate": args.rate,
               "gen": args.gen, "families": {}}
    all_match = True
    for family, arch in FAMILIES:
        cfg = get_config(arch).reduced(n_layers=2, d_model=128, n_heads=4,
                                       vocab=512)
        params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
        eng = _engine(cfg, params, args)
        match = _differential(cfg, params, eng, args, rng)
        all_match &= match
        load = _poisson_load(cfg, eng, args, rng)
        results["families"][family] = {
            "arch": arch, "greedy_match": bool(match), **load}
        print(f"[{family:14s}] match={match} "
              f"completed={load['completed']} "
              f"tok/s={load['tokens_per_s']:.1f} "
              f"ttft p50={load['ttft_ms']['p50']:.1f}ms "
              f"p99={load['ttft_ms']['p99']:.1f}ms "
              f"latency p50={load['latency_ms']['p50']:.1f}ms", flush=True)
    write_json("BENCH_serve", results)
    assert all_match, "engine greedy outputs diverged from the reference"


if __name__ == "__main__":
    main()
