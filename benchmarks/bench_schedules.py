import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")

"""Apples-to-apples cross-schedule throughput benchmark (ROADMAP item).

All six ``SCHEDULES`` run through the *same* ``SpmdRunner`` (shard_map
runtime + in-mesh AdamW) on fake CPU devices, so relative wall-clock is a
property of the schedule alone: same model, same data, same mesh, same
fused train step.  For each kind we report

  * measured wall-clock per step and per lockstep *slot* (the SPMD runtime
    executes the slot grid rows in sequence, so ms/slot is the measured
    analogue of the simulator's unit time);
  * the ``core/simulator`` prediction: total time units, predicted bubble
    fraction (pp_bubble_mean / total), and predicted relative throughput
    normalised to the best schedule.

Fake-device caveat: all devices share one CPU, so measured slot time folds
every stage's compute into one core and bubbles show up as *less* work per
slot, not idle silicon — rank agreement (and slot counts), not absolute
ratios, is the comparable signal.  Emits ``experiments/BENCH_schedules.json``.

  XLA_FLAGS=--xla_force_host_platform_device_count=2 \
  PYTHONPATH=src python -m benchmarks.bench_schedules
"""
import jax
import jax.numpy as jnp

from benchmarks.common import T_B, T_F, T_W, time_runner, write_json
from repro.api import make_runner
from repro.configs import get_config
from repro.core.schedule import SCHEDULES, build
from repro.core.simulator import StageTimes, simulate
from repro.data import DataConfig, make_batches
from repro.models import model as M
from repro.optim import OptConfig
from repro.pipeline import slots as SL


def main(pp: int = 2, m: int = 4, steps: int = 4, warmup: int = 1):
    ndev = len(jax.devices())
    assert ndev % pp == 0, f"{ndev} devices not divisible by pp={pp}"
    tp = ndev // pp
    cfg = get_config("qwen3-4b").reduced(n_layers=4, d_model=64, n_heads=4,
                                         vocab=256)
    oc = OptConfig(lr=1e-3, warmup_steps=2, total_steps=steps)
    dc = DataConfig(seq_len=32, global_batch=4 * m, microbatches=m)
    batches = [{k: jnp.asarray(v) for k, v in raw.items()}
               for raw in make_batches(cfg, dc, steps)]
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    results = {}
    for kind in SCHEDULES:
        tables, pl = build(kind, pp, m)
        n_slots = len(SL.encode(SL.to_slots(tables, pl), pl))
        sim = simulate(tables, pl,
                       StageTimes.uniform(pl.n_vs, t_f=T_F, t_b=T_B,
                                          t_w=T_W, t_ar=0.0), m)
        runner = make_runner("spmd", cfg, oc, dc, schedule=kind, pp=pp,
                             tp=tp)
        state = runner.init_state(params)
        wall, state, metrics = time_runner(runner, state, batches,
                                           warmup=warmup)
        results[kind] = {
            "placement": pl.kind,
            "n_slots": n_slots,
            "wall_s_per_step": round(wall, 4),
            "wall_ms_per_slot": round(1e3 * wall / n_slots, 3),
            "sim_total_units": sim.total_time,
            "sim_bubble_frac": round(float(sim.pp_bubble.mean()
                                           / sim.total_time), 4),
            "loss": round(float(metrics["loss"]), 4),
        }
        print(f"[{kind:10s}] {results[kind]}", flush=True)

    best_sim = min(r["sim_total_units"] for r in results.values())
    best_wall = min(r["wall_s_per_step"] for r in results.values())
    for r in results.values():
        r["sim_rel_throughput"] = round(best_sim / r["sim_total_units"], 4)
        r["wall_rel_throughput"] = round(best_wall / r["wall_s_per_step"], 4)
    write_json("BENCH_schedules", {
        "setup": {"pp": pp, "tp": tp, "microbatches": m, "steps": steps,
                  "arch": cfg.name, "devices": ndev,
                  "runner": "SpmdRunner (fused in-mesh AdamW)"},
        "schedules": results,
    })


if __name__ == "__main__":
    main()
