"""Apples-to-apples cross-schedule throughput benchmark (ROADMAP item).

All six ``SCHEDULES`` run through the *same* ``SpmdRunner`` (shard_map
runtime + in-mesh AdamW) on fake CPU devices, so relative wall-clock is a
property of the schedule alone: same model, same data, same mesh, same
fused train step.  For each kind we report

  * measured wall-clock per step (best-of-``--repeats`` mean over
    ``--steps`` steady steps, with the repeats *interleaved round-robin*
    across kinds so slow CPU-clock drift cannot bias whichever kind is
    measured first) for BOTH slot lowerings — the segment-
    fused default (``fuse_slots=True``: trace-time branch dispatch, pruned
    exchanges) and the generic one-switch-per-slot scan — plus the static
    plan counters (``n_segments`` / ``n_dispatches`` / ``n_ppermutes``)
    behind the difference;
  * the ``core/simulator`` prediction: total time units with per-virtual-
    stage unit times scaled by layers-per-vs (flat placement packs
    ``n_layers/p`` layers into each vs, vshape/parallel pack half that, so
    unscaled unit times are not comparable across placements), predicted
    bubble fraction, and predicted relative throughput normalised to the
    best schedule.

``--breakdown`` additionally times ablated program variants per lowering
and decomposes a step into

  compute   — branch-body FLOPs       (t_noexchange - t_skeleton)
  exchange  — ppermute boundary traffic (t_full - t_noexchange)
  dispatch  — switch/scan/slot-loop machinery (t_skeleton)

where ``t_skeleton`` ablates both compute and exchange but keeps the full
dispatch structure (stub branches preserve the loss data-dependence so XLA
cannot dead-code the skeleton).  Shares are relative to t_full.  On a
mesh with a model axis (tp > 1) the breakdown adds a TP-collective column:
``tp_collective_s = t_full - t_ablate_tp`` (``ablate="tp"`` executes the
same math with an identity TPContext) and ``tp_exposed_share_hlo``, the
structurally exposed share of model-axis collectives in the compiled HLO
(``launch.hlo_analysis.collective_overlap``).

Fake-device caveat: all devices share one CPU, so measured slot time folds
every stage's compute into one core and bubbles show up as *less* work per
slot, not idle silicon — rank agreement (and the overhead split), not
absolute ratios, is the comparable signal.  Emits
``experiments/BENCH_schedules.json``.

  PYTHONPATH=src python -m benchmarks.bench_schedules [--pp 2] [--m 4] \
      [--steps 4] [--warmup 1] [--breakdown] [--kinds gpipe,zb-v]
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from benchmarks.common import T_B, T_F, T_W, time_runner, write_json
from repro.api import make_runner
from repro.launch.hlo_analysis import collective_overlap
from repro.configs import get_config
from repro.core.schedule import SCHEDULES, build
from repro.core.simulator import StageTimes, simulate
from repro.data import DataConfig, make_batches, microbatches
from repro.models import model as M
from repro.optim import OptConfig
from repro.pipeline import slots as SL
from repro.pipeline.spmd import build_pipeline_step, stack_stage_params


def _time_fn(fn, args, *, steps, warmup, repeats=2):
    """Best-of-``repeats`` mean step time (min filters scheduler noise on
    the shared-core fake-device setup)."""
    out = None
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    best = None
    for _ in range(repeats):
        t0 = time.time()
        for _ in range(steps):
            out = fn(*args)
            jax.block_until_ready(out)
        dt = (time.time() - t0) / steps
        best = dt if best is None else min(best, dt)
    return best


def _breakdown(cfg, tables, pl, mesh, m, mb_shape, stacked, tokens, labels,
               *, fuse, steps, warmup, tp=1):
    """compute/exchange/dispatch (+ TP-collective when tp > 1) split via
    ablated program variants."""
    t, hlo_tp = {}, None
    model_axis = "model" if tp > 1 else None
    ablations = (None, "exchange", "both") + (("tp",) if tp > 1 else ())
    for ablate in ablations:
        step = build_pipeline_step(cfg, tables, pl, mesh, m, mb_shape,
                                   stacked, model_axis=model_axis,
                                   fuse_slots=fuse, ablate=ablate)
        if ablate is None and tp > 1:
            compiled = step.lower(*stacked, tokens, labels).compile()
            hlo_tp = collective_overlap(compiled.as_text(), tp_size=tp)["tp"]
        with mesh:
            t[ablate] = _time_fn(step, (*stacked, tokens, labels),
                                 steps=steps, warmup=warmup)
    full, noex, skel = t[None], t["exchange"], t["both"]
    out = {
        "t_full_s": round(full, 4),
        "compute_s": round(max(noex - skel, 0.0), 4),
        "exchange_s": round(max(full - noex, 0.0), 4),
        "dispatch_s": round(skel, 4),
        "dispatch_share": round(skel / full, 4),
        "exchange_share": round(max(full - noex, 0.0) / full, 4),
    }
    if "tp" in t:
        tp_s = max(full - t["tp"], 0.0)
        out["tp_collective_s"] = round(tp_s, 4)
        out["tp_collective_share"] = round(tp_s / full, 4)
        out["tp_exposed_share_hlo"] = round(hlo_tp["exposed_share"], 4)
    return out


def main(pp: int = 2, m: int = 4, steps: int = 8, warmup: int = 1,
         repeats: int = 3, breakdown: bool = False, kinds=None,
         d_model: int = 128, seq_len: int = 32):
    ndev = len(jax.devices())
    assert ndev % pp == 0, f"{ndev} devices not divisible by pp={pp}"
    tp = ndev // pp
    cfg = get_config("qwen3-4b").reduced(n_layers=4, d_model=d_model,
                                         n_heads=4, vocab=256)
    oc = OptConfig(lr=1e-3, warmup_steps=2, total_steps=steps)
    dc = DataConfig(seq_len=seq_len, global_batch=4 * m, microbatches=m)
    batches = [{k: jnp.asarray(v) for k, v in raw.items()}
               for raw in make_batches(cfg, dc, steps)]
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    # Phase 1 — build, compile and warm every (kind, lowering) program.
    # Timing happens in phase 2, round-robin across kinds, so slow drift of
    # the shared fake-device CPU (turbo/thermal decay over a ~20 min run)
    # cannot systematically favour whichever kind is measured first.
    results, prog = {}, {}
    for kind in kinds or SCHEDULES:
        tables, pl = build(kind, pp, m)
        codes = SL.encode(SL.to_slots(tables, pl), pl)
        # Per-vs unit times must scale with how many layers one vs holds:
        # flat packs n_layers/p per vs, vshape/parallel n_layers/(2p).
        lvs = cfg.n_layers / pl.n_vs
        sim = simulate(tables, pl,
                       StageTimes.uniform(pl.n_vs, t_f=T_F * lvs,
                                          t_b=T_B * lvs, t_w=T_W * lvs,
                                          t_ar=0.0), m)
        loss = None
        for fuse in (True, False):
            runner = make_runner("spmd", cfg, oc, dc, schedule=kind, pp=pp,
                                 tp=tp, fuse_slots=fuse)
            state = runner.init_state(params)
            state, metrics = runner.step(state, batches[0])   # compile
            prog[(kind, fuse)] = (runner, state)
            if fuse:
                loss = float(metrics["loss"])
        stats = SL.plan_stats(codes, pl.kind, fused=True)
        stats_g = SL.plan_stats(codes, pl.kind, fused=False)
        results[kind] = {
            "placement": pl.kind,
            "n_slots": stats["n_slots"],
            "n_segments": stats["n_segments"],
            "n_dispatches": stats["n_dispatches"],
            "n_ppermutes": stats["n_ppermutes"],
            "n_dispatches_generic": stats_g["n_dispatches"],
            "n_ppermutes_generic": stats_g["n_ppermutes"],
            "sim_total_units": sim.total_time,
            "sim_bubble_frac": round(float(sim.pp_bubble.mean()
                                           / sim.total_time), 4),
            "loss": round(loss, 4),
            "_tables_pl": (tables, pl),
        }
        print(f"[{kind:10s}] compiled ({stats})", flush=True)

    # Phase 2 — interleaved timing, best-of-repeats per program.
    walls = {}
    for rep in range(repeats):
        for (kind, fuse), (runner, state) in prog.items():
            w, state, _ = time_runner(runner, state, batches, warmup=warmup)
            prog[(kind, fuse)] = (runner, state)
            key = (kind, fuse)
            walls[key] = w if key not in walls else min(walls[key], w)
        print(f"[round {rep + 1}/{repeats}] "
              + " ".join(f"{k}{'+' if f else '-'}={walls[(k, f)]:.3f}"
                         for k, f in walls), flush=True)
    for kind in list(results):
        r = results[kind]
        tables, pl = r.pop("_tables_pl")
        r["wall_s_per_step"] = round(walls[(kind, True)], 4)
        r["wall_s_per_step_unfused"] = round(walls[(kind, False)], 4)
        r["wall_ms_per_slot"] = round(1e3 * walls[(kind, True)]
                                      / r["n_slots"], 3)
        if breakdown:
            mb = dc.global_batch // dc.microbatches
            mesh = Mesh(np.array(jax.devices()).reshape(pp, tp),
                        ("stage", "model"))
            c0, c1, _ = stack_stage_params(params, cfg, pp, kind=pl.kind)
            stacked = (c0, c1, params["embed"], params["head"])
            mbs = microbatches(batches[0], m)
            tokens = jnp.stack([b["tokens"] for b in mbs])
            labels = jnp.stack([b["labels"] for b in mbs])
            r["breakdown"] = {
                "fused" if f else "generic": _breakdown(
                    cfg, tables, pl, mesh, m, (mb, dc.seq_len), stacked,
                    tokens, labels, fuse=f, steps=steps, warmup=warmup,
                    tp=tp)
                for f in (True, False)}
        print(f"[{kind:10s}] {r}", flush=True)

    best_sim = min(r["sim_total_units"] for r in results.values())
    best_wall = min(r["wall_s_per_step"] for r in results.values())
    for r in results.values():
        r["sim_rel_throughput"] = round(best_sim / r["sim_total_units"], 4)
        r["wall_rel_throughput"] = round(best_wall / r["wall_s_per_step"], 4)
    write_json("BENCH_schedules", {
        "setup": {"pp": pp, "tp": tp, "microbatches": m, "steps": steps,
                  "repeats": repeats,
                  "arch": cfg.name, "d_model": d_model,
                  "seq_len": seq_len, "devices": ndev,
                  "runner": "SpmdRunner (fused in-mesh AdamW)",
                  "lowering": "segment-fused (+ generic comparison)"},
        "schedules": results,
    })


if __name__ == "__main__":
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--pp", type=int, default=2)
    ap.add_argument("--m", type=int, default=4)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--d-model", type=int, default=128, dest="d_model")
    ap.add_argument("--seq-len", type=int, default=32, dest="seq_len")
    ap.add_argument("--breakdown", action="store_true",
                    help="time ablated variants: compute/exchange/dispatch "
                         "split per lowering")
    ap.add_argument("--kinds", type=lambda s: s.split(","), default=None,
                    help="comma-separated subset of schedules")
    args = ap.parse_args()
    main(**vars(args))
