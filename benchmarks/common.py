"""Shared benchmark plumbing: A800-calibrated unit times and CSV helpers.

Calibration (§5.1 setups, 16/32 A800s, fp/bf16):
  * per-virtual-stage compute is identical across the paper's 16-GPU
    configs (TP x PP x 2 chunks = 64 GPU-chunks of a fixed model), so
    T_F = 2, T_B = 2, T_W = 1 time units everywhere;
  * Fig. 1: TP All-Reduce share of a forward chunk is 27.5% at TP=8, PP=2,
    seq 6144 -> T_AR = 0.76; other (TP, PP) scale T_AR by
    (layers/vs ratio) x (ring factor (t-1)/t);
  * sequence length scales T_AR slightly sub-linearly vs compute (attention
    is quadratic, comm linear): T_AR(seq) ~ T_AR * (6144/seq)**0.15.
"""
from __future__ import annotations

import csv
import io
import json
import sys
import time
from pathlib import Path

from repro.core.simulator import StageTimes

OUT = Path(__file__).resolve().parent.parent / "experiments"

T_F, T_B, T_W = 2.0, 2.0, 1.0
AR_REF = 0.76          # TP=8, PP=2, seq 6144 (Fig. 1 calibration)


def t_ar_for(tp: int, pp: int, seq: int = 6144, ref_seq: int = 6144) -> float:
    ring = (tp - 1) / tp / ((8 - 1) / 8)
    layers_per_vs = 1.0 / pp / (1.0 / 2)         # vs PP=2 reference
    seq_f = (ref_seq / max(seq, 1)) ** 0.15
    return AR_REF * ring * layers_per_vs * seq_f


def times_for(tp: int, pp: int, seq: int = 6144, t_comm: float = 0.0,
              vit_factor: float = 1.0) -> StageTimes:
    t = StageTimes.uniform(2 * pp, t_f=T_F, t_b=T_B, t_w=T_W,
                           t_ar=t_ar_for(tp, pp, seq), m_a=1.0,
                           t_comm=t_comm)
    if vit_factor != 1.0:
        t = t.scaled_vs(0, vit_factor)
    return t


def write_json(name: str, obj) -> Path:
    OUT.mkdir(parents=True, exist_ok=True)
    path = OUT / f"{name}.json"
    text = json.dumps(obj, indent=1)
    path.write_text(text)
    print(f"--- {name} ({path}) ---")
    print(text)
    return path


def time_runner(runner, state, batches, *, warmup: int = 1):
    """Drive any ``repro.api.Runner`` over ``batches`` and return
    (seconds per steady-state step, final state, last metrics).  The first
    ``warmup`` steps (compile + cache fill) are excluded."""
    import jax                       # lazy: most benchmarks are sim-only

    batches = list(batches)
    if warmup >= len(batches):
        raise ValueError(
            f"time_runner needs at least one steady-state step: "
            f"warmup={warmup} >= len(batches)={len(batches)}; pass more "
            f"batches or a smaller warmup (the timer would otherwise "
            f"report ~0 s/step)")
    t0 = time.time()
    metrics = {}
    for i, batch in enumerate(batches):
        state, metrics = runner.step(state, batch)
        jax.block_until_ready(metrics["loss"])
        if i + 1 == warmup:
            t0 = time.time()
    steady = len(batches) - warmup
    return (time.time() - t0) / steady, state, metrics


def write_csv(name: str, header, rows):
    OUT.mkdir(parents=True, exist_ok=True)
    path = OUT / f"{name}.csv"
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    buf = io.StringIO()
    w = csv.writer(buf)
    w.writerow(header)
    w.writerows(rows)
    print(f"--- {name} ({path}) ---")
    print(buf.getvalue())
    return path
