"""Paper Table 1: theoretical PP bubble / TP bubble / peak activation
memory vs the event-driven simulator, for 1F1B-I, ZB-V and STP."""
from repro.core.schedule import run as run_schedule
from repro.core.simulator import StageTimes
from repro.core.theory import THEORY, UnitTimes

from benchmarks.common import T_B, T_F, T_W, write_csv


def main():
    rows = []
    u = UnitTimes(t_f=T_F, t_b=T_B, t_w=T_W, t_ar=0.5)
    for p, m in [(2, 64), (4, 64), (8, 96)]:
        times = StageTimes.uniform(2 * p, t_f=u.t_f, t_b=u.t_b, t_w=u.t_w,
                                   t_ar=u.t_ar, m_a=u.m_a)
        for kind in ("1f1b-i", "zb-v", "stp"):
            th = THEORY[kind](p, m, u)
            res, _, _ = run_schedule(kind, p, m, times)
            s = res.summary()
            rows.append([
                kind, p, m,
                round(th.pp_bubble, 2), round(s["pp_bubble_mean"], 2),
                round(th.tp_bubble, 2), round(s["tp_exposed_mean"], 2),
                round(th.peak_act_memory, 1), round(s["peak_mem_max"], 1),
            ])
    write_csv("table1_theory",
              ["schedule", "p", "m", "pp_bubble_theory", "pp_bubble_sim",
               "tp_bubble_theory", "tp_bubble_sim", "peak_mem_theory",
               "peak_mem_sim"], rows)


if __name__ == "__main__":
    main()
