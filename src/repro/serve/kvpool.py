"""Paged KV/state pool: host-side block & row accounting for the serve engine.

The device side holds, per data shard, a physical pool of ``blocks + 1``
fixed-size KV blocks per attention layer (the last block is the *garbage*
block: never allocated, its slot positions stay -1 so reads of it are always
masked) plus one recurrent-state slot per engine row for SSM layers.  This
module owns the matching host-side accounting:

* a free-list of **rows** (continuous-batching slots) per data shard,
* a free-list of **blocks** per data shard,
* the **block table** (rows x width) of *local* block ids that the jitted
  decode/prefill steps index with — unallocated entries point at the garbage
  block.

Admission control is explicit: ``can_admit(need)`` answers whether any shard
has a free row and ``need`` free blocks; the scheduler queues (or the engine
rejects) requests that do not fit — the pool is a fixed memory budget, not a
per-request allocation (cf. "Pipeline Parallelism with Controllable Memory").

Block lifetimes never touch the device: freeing is a host-side list append +
table reset, and stale device-side block contents are neutralised by the
*next* prefill, which clears the ``pos`` slots of every block it allocates
before writing (positions of -1 are masked out of attention exactly like an
empty ring slot in ``model._attn_decode``).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class PoolConfig:
    rows: int                 # continuous-batching slots (global, over shards)
    blocks: int               # usable KV blocks per data shard (+1 garbage)
    block_size: int           # tokens per block
    max_seq: int              # longest prompt+generation the table can map
    data: int = 1             # data shards (rows/blocks are per-shard local)

    @property
    def width(self) -> int:
        """Block-table width: blocks needed to map ``max_seq`` positions."""
        return -(-self.max_seq // self.block_size)

    @property
    def garbage(self) -> int:
        """Local id of the never-allocated garbage block (= ``blocks``)."""
        return self.blocks

    @property
    def rows_local(self) -> int:
        return self.rows // self.data


@dataclass(frozen=True)
class Admission:
    row: int                  # global row id (shard-major)
    shard: int                # owning data shard
    row_local: int            # row index within the shard
    block_ids: tuple          # local block ids, table entries 0..need-1


class PagedPool:
    def __init__(self, pc: PoolConfig):
        import numpy as np
        if pc.rows % pc.data:
            raise ValueError(f"rows={pc.rows} not divisible by data={pc.data}")
        if pc.blocks < 1 or pc.block_size < 1:
            raise ValueError("need at least one block of at least one token")
        self.pc = pc
        self.table = np.full((pc.rows, pc.width), pc.garbage, np.int32)
        self._free_rows = [deque(range(pc.rows_local)) for _ in range(pc.data)]
        self._free_blocks = [deque(range(pc.blocks)) for _ in range(pc.data)]
        self._held = {}       # global row -> Admission

    # -- introspection ------------------------------------------------------

    def free_rows(self, shard: int) -> int:
        return len(self._free_rows[shard])

    def free_blocks(self, shard: int) -> int:
        return len(self._free_blocks[shard])

    @property
    def active_rows(self) -> int:
        return len(self._held)

    # -- admission ----------------------------------------------------------

    def can_admit(self, need: int) -> Optional[int]:
        """Shard that can hold a request needing ``need`` blocks, or None."""
        if need > self.pc.blocks:
            return None
        for d in range(self.pc.data):
            if self._free_rows[d] and len(self._free_blocks[d]) >= need:
                return d
        return None

    def admit(self, need: int) -> Admission:
        d = self.can_admit(need)
        if d is None:
            raise RuntimeError(f"pool full: cannot admit need={need}")
        rl = self._free_rows[d].popleft()
        ids = tuple(self._free_blocks[d].popleft() for _ in range(need))
        row = d * self.pc.rows_local + rl
        self.table[row, :] = self.pc.garbage
        self.table[row, : len(ids)] = ids
        adm = Admission(row, d, rl, ids)
        self._held[row] = adm
        return adm

    def release(self, row: int) -> None:
        adm = self._held.pop(row)
        self._free_blocks[adm.shard].extend(adm.block_ids)
        self._free_rows[adm.shard].append(adm.row_local)
        self.table[row, :] = self.pc.garbage
