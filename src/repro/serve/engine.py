"""Mesh-resident continuous-batching serve engine.

One ``("data", "model")`` mesh holds the model weights, the paged KV pools
and the SSM state slots for the whole engine lifetime; requests stream
through two jitted ``shard_map`` programs:

* **prefill** — ONE forward over the (bucket-padded) prompt batch: flash
  attention for attention layers with the rope'd/normed KV scattered into
  the rows' allocated blocks, and a single masked ``lax.scan`` of the decode
  step for recurrent mixers (bit-identical state handoff, see
  ``ssm.prefill_scan``).  Emits each row's first generated token.
* **decode** — one token for *every* active row per tick
  (``model.decode_layer_paged``): per-row positions, block-table addressed
  paged KV with ring reuse for sliding-window layers, frozen state for
  inactive rows.  Greedy next-token via a vocab-parallel head + tiled
  ``all_gather`` + argmax (bitwise identical per column under TP).

Tensor parallelism reuses ``TPContext`` in unit mode on the ``model`` axis,
exactly as ``pipeline/spmd.py`` does for training — Megatron col/row rules
per mixer (sLSTM layers run replicated: their four interleaved gate blocks
do not shard, see DESIGN.md).  Rows and KV blocks shard over ``data``;
prefill compute is replicated across data shards with owner-masked scatters
(non-owner writes are dropped), so a prefill group may mix rows from
different shards.

The host side (this class) is the scheduler loop: each ``step()`` admits
queued requests that fit the pool, prefills them *while previously admitted
rows keep decoding*, decodes every active row, and retires rows that hit
their token budget — freed blocks return to the pool immediately and are
reused by later admissions (the next prefill clears their stale slot
positions on-device).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model as M
from repro.models import ssm, units
from repro.models.config import LayerSpec, ModelConfig
from repro.serve.kvpool import PagedPool, PoolConfig
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import Request, Scheduler
from repro.tp.context import TPContext


@dataclass(frozen=True)
class EngineConfig:
    tp: int = 1               # model-axis size (TP)
    data: int = 1             # data-axis size (rows/blocks shard over this)
    rows: int = 8             # concurrent sequences (global over data shards)
    blocks: int = 64          # usable KV blocks per data shard
    block_size: int = 8       # tokens per KV block
    max_seq: int = 256        # prompt + generation ceiling per request
    max_queue: int = 64       # queued (not yet admitted) request ceiling
    prefill_group: int = 2    # fixed prefill batch (padded with dummy rows)
    prefill_bucket: int = 16  # prompt-length padding granularity


# ---------------------------------------------------------------------------
# TP sharding rules per mixer (serve-local: the training-side ``_tp_axis_of``
# has no rules for the mamba core, and would wrongly shard sLSTM's w_down).
# ---------------------------------------------------------------------------

_COL = {"wq", "wk", "wv", "wg", "wu", "w1", "w_in_x", "w_in_z",
        "w_upx", "w_upz"}
_ROW = {"wo", "wd", "w2", "w_out", "w_down"}


def _axis_of(mixer: str, name: str) -> Optional[int]:
    if mixer == "slstm":
        return None               # replicated: gate blocks interleave
    if mixer == "mlstm" and name in ("wq", "wk", "wv"):
        return -3                 # (nh, hd, hd): shard heads
    if name in ("wi", "wf"):
        return -2                 # (nh, hd) gate heads
    if name in _COL:
        return -1
    if name in _ROW:
        return -2
    if name in ("conv_w", "w_x", "A_log"):
        return -2                 # mamba core: inner dim leads
    if name in ("w_dt",):
        return -1                 # (r, di): di-split output
    if name in ("conv_b", "dt_bias", "D"):
        return -1                 # (di,) per-channel vectors
    return None                   # norms, biases, router, slstm core


def _leaf_name(path) -> Optional[str]:
    for k in reversed(path):
        if hasattr(k, "key"):
            return k.key
    return None


def serve_param_specs(tree, mixer: str, model_axis: Optional[str]):
    """PartitionSpec tree for one (period-stacked) layer's params."""
    def one(path, leaf):
        spec = [None] * leaf.ndim
        ax = _axis_of(mixer, _leaf_name(path)) if model_axis else None
        if ax is not None:
            spec[leaf.ndim + ax] = model_axis
        return P(*spec)
    return jax.tree_util.tree_map_with_path(one, tree)


def serve_cache_specs(spec: LayerSpec, tree):
    """PartitionSpec tree for one period position's cache: leading (reps,)
    replicated, rows/blocks over ``data``, head-or-inner dims over ``model``
    (sLSTM states replicate across model ranks along with their params)."""
    mixer = spec.mixer

    def one(path, leaf):
        name = _leaf_name(path)
        s = [None] * leaf.ndim
        s[1] = "data"
        if mixer == "attn" and name in ("k", "v"):
            s[2] = "model"
        elif mixer == "mamba":
            if name == "h":
                s[2] = "model"        # (reps, rows, di, n)
            elif name == "conv":
                s[3] = "model"        # (reps, rows, ck-1, di)
        elif mixer == "mlstm":
            s[2] = "model"            # C/n/m all lead with nh
        return P(*s)
    return jax.tree_util.tree_map_with_path(one, tree)


def _head_specs(tree):
    def one(path, leaf):
        if _leaf_name(path) == "w_lm":
            return P(None, "model")   # vocab-parallel head
        return P()
    return jax.tree_util.tree_map_with_path(one, tree)


def stacked_params(cfg: ModelConfig, params):
    """Canonical params -> the period-stacked layout the serve paths scan."""
    return {"embed": params["embed"],
            "blocks": M.stack_blocks(params["blocks"], M.period_of(cfg)),
            "head": params["head"]}


def blocks_needed(cfg: ModelConfig, block_size: int, width: int,
                  plen: int, max_new: int) -> int:
    """KV blocks a request must hold: the max over attention layers of the
    blocks that layer will address for ``plen + max_new`` positions —
    ``ceil(L/bs)`` for global layers, the ring size for windowed ones.
    SSM-only architectures need zero (their state slot is per-row, not
    pooled) — real per-family admission differences."""
    L = plen + max_new
    need = 0
    for spec in set(cfg.layers):
        if spec.mixer != "attn":
            continue
        ring = M.attn_ring_blocks(spec, block_size, width)
        need = max(need, min(-(-L // block_size), ring))
    return need


def _validate(cfg: ModelConfig, ecfg: EngineConfig) -> None:
    if cfg.frontend != "text" or not cfg.causal:
        raise ValueError(f"{cfg.name}: serve engine decodes causal text only")
    tp = ecfg.tp
    if tp <= 1:
        return
    checks = [("vocab", cfg.vocab)]
    for spec in set(cfg.layers):
        if spec.mixer == "attn":
            checks += [("n_heads", cfg.n_heads), ("kv_heads", cfg.kv_heads)]
        elif spec.mixer == "mamba":
            checks += [("mamba inner dim", cfg.ssm_expand * cfg.d_model)]
        elif spec.mixer == "mlstm":
            checks += [("n_heads", cfg.n_heads)]
        if spec.mlp in ("gated", "plain"):
            checks += [("d_ff", cfg.d_ff)]
        elif spec.mlp == "moe":
            checks += [("moe d_ff", cfg.moe.d_ff)]
    for what, dim in checks:
        if dim % tp:
            raise ValueError(f"{cfg.name}: {what}={dim} not divisible by "
                             f"tp={tp}")


class Engine:
    """Continuous-batching serve engine over canonical ``init_params``-style
    parameters.  ``submit`` requests, drive with ``step()``/``run()``, or use
    ``generate`` for a synchronous batch."""

    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 devices=None):
        _validate(cfg, ecfg)
        bucket = max(ecfg.prefill_bucket, ecfg.block_size)
        bucket = -(-bucket // ecfg.block_size) * ecfg.block_size
        self.cfg = cfg
        self.ecfg = ecfg
        self._bucket = bucket
        self.period = M.period_of(cfg)
        self.specs = cfg.layers[: self.period]
        self.reps = cfg.n_layers // self.period
        self._tps = [TPContext() if s.mixer == "slstm"
                     else TPContext("model", ecfg.tp) for s in self.specs]

        devs = list(devices if devices is not None else jax.devices())
        n_dev = ecfg.data * ecfg.tp
        if len(devs) < n_dev:
            raise ValueError(f"need {n_dev} devices (data={ecfg.data} x "
                             f"tp={ecfg.tp}), have {len(devs)}")
        self.mesh = Mesh(np.array(devs[:n_dev]).reshape(ecfg.data, ecfg.tp),
                         ("data", "model"))

        self.pool = PagedPool(PoolConfig(ecfg.rows, ecfg.blocks,
                                         ecfg.block_size, ecfg.max_seq,
                                         ecfg.data))
        self.scheduler = Scheduler(ecfg.max_queue)
        self.metrics = ServeMetrics()

        # --- place params + caches mesh-resident --------------------------
        st = stacked_params(cfg, params)
        self._bspecs = [serve_param_specs(st["blocks"][i],
                                          self.specs[i].mixer, "model")
                        for i in range(self.period)]
        self._espec = jax.tree.map(lambda _: P(), st["embed"])
        self._hspec = _head_specs(st["head"])
        nsh = lambda spec: jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), spec)
        self.params = {
            "blocks": [jax.device_put(st["blocks"][i], nsh(self._bspecs[i]))
                       for i in range(self.period)],
            "embed": jax.device_put(st["embed"], nsh(self._espec)),
            "head": jax.device_put(st["head"], nsh(self._hspec)),
        }
        self._cspecs = [serve_cache_specs(self.specs[i], c)
                        for i, c in enumerate(self._cache_shapes())]
        self.caches = [jax.device_put(c, nsh(self._cspecs[i]))
                       for i, c in enumerate(self._cache_shapes())]

        self._sh_rows = NamedSharding(self.mesh, P("data"))
        self._sh_rep = NamedSharding(self.mesh, P())

        # --- host row state ------------------------------------------------
        self._pos = np.full(ecfg.rows, -1, np.int32)   # next write position
        self._tok = np.zeros(ecfg.rows, np.int32)      # token to feed there
        self._row_req: list = [None] * ecfg.rows
        self._next_rid = 0
        self.requests = {}                             # rid -> Request

        self._decode = self._build_decode()
        self._prefills = {}                            # bucket len -> jit fn

    # ------------------------------------------------------------------
    # device programs
    # ------------------------------------------------------------------

    def _cache_shapes(self):
        """Host-side zero caches in global (unsharded) shapes, f32 KV/state
        so the paged path is bit-comparable to an f32-cache reference."""
        e, cfg = self.ecfg, self.cfg
        out = []
        for i in range(self.period):
            spec, reps, rows = self.specs[i], self.reps, e.rows
            if spec.mixer == "attn":
                nb = e.data * (e.blocks + 1)
                kv = (reps, nb, cfg.kv_heads, e.block_size, cfg.hd)
                out.append({"k": jnp.zeros(kv, jnp.float32),
                            "v": jnp.zeros(kv, jnp.float32),
                            "pos": jnp.full((reps, nb, e.block_size), -1,
                                            jnp.int32)})
            elif spec.mixer == "mamba":
                di = cfg.ssm_expand * cfg.d_model
                out.append({"h": jnp.zeros((reps, rows, di, cfg.ssm_state),
                                           jnp.float32),
                            "conv": jnp.zeros((reps, rows, cfg.ssm_conv - 1,
                                               di), jnp.float32)})
            elif spec.mixer == "mlstm":
                du, nh, hd = ssm.mlstm_dims(cfg)
                out.append({"C": jnp.zeros((reps, rows, nh, hd, hd),
                                           jnp.float32),
                            "n": jnp.zeros((reps, rows, nh, hd), jnp.float32),
                            "m": jnp.full((reps, rows, nh), -1e30,
                                          jnp.float32)})
            elif spec.mixer == "slstm":
                du, _, _ = ssm.slstm_dims(cfg)
                z = lambda: jnp.zeros((reps, rows, du), jnp.float32)
                out.append({"c": z(), "n": z(), "h": z(),
                            "m": jnp.full((reps, rows, du), -1e30,
                                          jnp.float32)})
            else:
                raise ValueError(spec.mixer)
        return out

    def _head_token(self, head_p, x_last):
        """x_last (b, 1, d) replicated -> greedy token (b,).  Local vocab
        shard logits, tiled all-gather, argmax — each logit column is the
        full-d contraction, so the argmax is bitwise TP-invariant."""
        x_ln, _ = units.prenorm_fwd(head_p["ln_f"], x_last, self.cfg)
        logits = jnp.einsum("bsd,dv->bsv", x_ln, head_p["w_lm"])[:, 0]
        logits = jax.lax.all_gather(logits, "model", axis=1, tiled=True)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def _build_decode(self):
        cfg, period, specs, tps = self.cfg, self.period, self.specs, self._tps

        def body(blocks_p, embed_p, head_p, caches, tables, pos, toks):
            x = jnp.take(embed_p["emb"], toks, axis=0)[:, None, :]
            active = pos >= 0
            new_caches = []
            for i in range(period):
                def lbody(x, pc, spec=specs[i], tpc=tps[i]):
                    lp, cache = pc
                    y, nc = M.decode_layer_paged(lp, tpc, x, cache, tables,
                                                 pos, active, spec, cfg)
                    return y, nc
                x, nc = jax.lax.scan(lbody, x, (blocks_p[i], caches[i]))
                new_caches.append(nc)
            return self._head_token(head_p, x), new_caches

        fn = shard_map(
            body, mesh=self.mesh,
            in_specs=(self._bspecs, self._espec, self._hspec, self._cspecs,
                      P("data", None), P("data"), P("data")),
            out_specs=(P("data"), self._cspecs),
            check_rep=False)
        return jax.jit(fn, donate_argnums=(3,))

    def _prefill_fn(self, S: int):
        if S in self._prefills:
            return self._prefills[S]
        cfg, period, specs, tps = self.cfg, self.period, self.specs, self._tps
        bs = self.ecfg.block_size
        rows_local = self.pool.pc.rows_local
        rope = units.rope_tables(S, cfg.hd, cfg.rope_theta)

        def body(blocks_p, embed_p, head_p, caches, tokens, lengths, owner,
                 rl, clear, dsts):
            own = owner == jax.lax.axis_index("data")        # (G,)
            x = jnp.take(embed_p["emb"], tokens, axis=0)     # (G, S, d)
            new_caches = []
            for i in range(period):
                def lbody(x, pc, spec=specs[i], tpc=tps[i], dst=dsts[i]):
                    lp, cache = pc
                    y, kv = M.prefill_layer(lp, tpc, x, rope, lengths, spec,
                                            cfg)
                    if spec.mixer == "attn":
                        nc = _scatter_kv(cache, kv, dst, clear, own, lengths,
                                         bs)
                    else:
                        # non-owner (and dummy-row) writes index OOB -> drop
                        rle = jnp.where(own, rl, rows_local)
                        nc = jax.tree.map(
                            lambda c, s: c.at[rle].set(
                                s.astype(c.dtype), mode="drop"), cache, kv)
                    return y, nc
                x, nc = jax.lax.scan(lbody, x, (blocks_p[i], caches[i]))
                new_caches.append(nc)
            idx = jnp.clip(lengths - 1, 0, S - 1)
            x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
            return self._head_token(head_p, x_last), new_caches

        rep = P()
        fn = shard_map(
            body, mesh=self.mesh,
            in_specs=(self._bspecs, self._espec, self._hspec, self._cspecs,
                      rep, rep, rep, rep, rep, [rep] * period),
            out_specs=(rep, self._cspecs),
            check_rep=False)
        self._prefills[S] = jax.jit(fn, donate_argnums=(3,))
        return self._prefills[S]

    # ------------------------------------------------------------------
    # request lifecycle
    # ------------------------------------------------------------------

    def submit(self, prompt, max_new: int) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, prompt, max_new)
        self.requests[rid] = req
        self.metrics.submit(rid)
        need = blocks_needed(self.cfg, self.ecfg.block_size, self.pool.pc.width,
                             req.plen, max_new)
        req.blocks_needed = need
        if (req.plen < 1 or max_new < 1
                or req.plen + max_new > self.ecfg.max_seq
                or need > self.ecfg.blocks
                or not self.scheduler.submit(req)):
            req.status = "rejected"
            self.metrics.reject(rid)
        return req

    def _retire(self, req: Request, finished: list) -> None:
        self.pool.release(req.row)
        self._pos[req.row] = -1
        self._tok[req.row] = 0
        self._row_req[req.row] = None
        req.status = "done"
        self.metrics.finish(req.rid, len(req.generated))
        finished.append(req)

    def _dst_map(self, spec: LayerSpec, block_ids, plen: int, S: int):
        """Per-row block destinations for the prefill KV scatter: chunk j of
        the padded prompt -> local block id, or -1 (dropped).  Global layers
        place chunk j in table entry j; windowed layers keep, per ring slot,
        only the *latest* chunk mapping to it (earlier occupants would be
        outside the window at first decode — see ring analysis in tests)."""
        bs = self.ecfg.block_size
        nB = S // bs
        dst = np.full(nB, -1, np.int32)
        last = (plen - 1) // bs
        if spec.window is None:
            for j in range(last + 1):
                dst[j] = block_ids[j]
        else:
            ring = M.attn_ring_blocks(spec, bs, self.pool.pc.width)
            for r in range(ring):
                j = last - ((last - r) % ring)
                if 0 <= j <= last:
                    dst[j] = block_ids[j % ring] if last < ring \
                        else block_ids[r]
        return dst

    def _prefill(self, admitted, finished) -> None:
        e = self.ecfg
        G = e.prefill_group
        S = -(-max(req.plen for req, _ in admitted) // self._bucket) \
            * self._bucket
        nB = S // e.block_size
        W = self.pool.pc.width
        tokens = np.zeros((G, S), np.int32)
        lengths = np.ones(G, np.int32)
        owner = np.full(G, -1, np.int32)
        rl = np.zeros(G, np.int32)
        clear = np.full((G, W), -1, np.int32)
        dsts = [np.full((G, nB), -1, np.int32) if s.mixer == "attn"
                else np.zeros((G, 1), np.int32) for s in self.specs]
        for gi, (req, adm) in enumerate(admitted):
            tokens[gi, : req.plen] = req.prompt
            lengths[gi] = req.plen
            owner[gi] = adm.shard
            rl[gi] = adm.row_local
            clear[gi, : len(adm.block_ids)] = adm.block_ids
            for i, spec in enumerate(self.specs):
                if spec.mixer == "attn":
                    dsts[i][gi] = self._dst_map(spec, adm.block_ids,
                                                req.plen, S)
            req.row = adm.row
            req.status = "active"
            self._row_req[adm.row] = req
            self.metrics.admit(req.rid)

        rep = lambda a: jax.device_put(a, self._sh_rep)
        first, self.caches = self._prefill_fn(S)(
            self.params["blocks"], self.params["embed"], self.params["head"],
            self.caches, rep(tokens), rep(lengths), rep(owner), rep(rl),
            rep(clear), [rep(d) for d in dsts])
        first = np.asarray(jax.device_get(first))
        for gi, (req, adm) in enumerate(admitted):
            req.generated.append(int(first[gi]))
            self.metrics.first_token(req.rid)
            self._pos[adm.row] = req.plen
            self._tok[adm.row] = int(first[gi])
            if len(req.generated) >= req.max_new:
                self._retire(req, finished)

    def _decode_tick(self, finished) -> None:
        rows = lambda a: jax.device_put(a, self._sh_rows)
        nxt, self.caches = self._decode(
            self.params["blocks"], self.params["embed"], self.params["head"],
            self.caches, rows(self.pool.table), rows(self._pos),
            rows(self._tok))
        nxt = np.asarray(jax.device_get(nxt))
        for row in np.nonzero(self._pos >= 0)[0]:
            req = self._row_req[row]
            req.generated.append(int(nxt[row]))
            if len(req.generated) >= req.max_new:
                self._retire(req, finished)
            else:
                self._tok[row] = int(nxt[row])
                self._pos[row] += 1

    def step(self) -> List[Request]:
        """One engine tick: admit + prefill (interleaved with in-flight
        decode state), decode every active row, retire finished requests.
        Returns the requests that finished this tick."""
        finished: List[Request] = []
        admitted = self.scheduler.take_admissible(self.pool,
                                                  self.ecfg.prefill_group)
        if admitted:
            self._prefill(admitted, finished)
        if np.any(self._pos >= 0):
            self._decode_tick(finished)
        self.metrics.tick(self.scheduler.depth, self.pool.active_rows)
        return finished

    def run(self, max_ticks: int = 100_000) -> List[Request]:
        """Drive ``step`` until queue and rows drain; returns all finished."""
        done: List[Request] = []
        for _ in range(max_ticks):
            if not self.scheduler.depth and not np.any(self._pos >= 0):
                return done
            done.extend(self.step())
        raise RuntimeError(f"engine did not drain in {max_ticks} ticks")

    def generate(self, prompts, max_new: int) -> List[np.ndarray]:
        """Synchronous batch: submit all, run to completion, return each
        request's prompt+generated tokens (rejected submissions raise)."""
        reqs = [self.submit(p, max_new) for p in prompts]
        bad = [r.rid for r in reqs if r.status == "rejected"]
        if bad:
            raise RuntimeError(f"requests rejected at submit: {bad}")
        self.run()
        return [r.tokens() for r in reqs]

    def reset_metrics(self) -> None:
        self.metrics.reset()


def _scatter_kv(cache, kv, dst, clear, own, lengths, bs: int):
    """Scatter one layer's prefill KV into its block pool (single rep slice,
    local shapes).  ``dst``/``clear`` hold local block ids or -1; non-owner
    rows, dummy rows and -1 entries are redirected out of bounds and dropped.
    Slot positions of every allocated block are cleared first, so blocks
    reused from a retired request cannot leak stale (maskable-looking)
    positions into later decode steps."""
    nbl = cache["k"].shape[0]
    g, kvh, s, hd = kv["k"].shape
    nB = dst.shape[1]
    ok = own[:, None]
    dste = jnp.where(ok & (dst >= 0), dst, nbl)
    cle = jnp.where(ok & (clear >= 0), clear, nbl)

    def chunks(a):                    # (G, kvh, S, hd) -> (G, nB, kvh, bs, hd)
        return a.reshape(g, kvh, nB, bs, hd).transpose(0, 2, 1, 3, 4)

    ck = cache["k"].at[dste].set(chunks(kv["k"]).astype(cache["k"].dtype),
                                 mode="drop")
    cv = cache["v"].at[dste].set(chunks(kv["v"]).astype(cache["v"].dtype),
                                 mode="drop")
    grid = jnp.arange(nB * bs, dtype=jnp.int32).reshape(nB, bs)
    pv = jnp.where(grid[None] < lengths[:, None, None], grid[None], -1)
    cpos = cache["pos"].at[cle].set(-1, mode="drop")
    cpos = cpos.at[dste].set(jnp.broadcast_to(pv, (g, nB, bs)), mode="drop")
    return {"k": ck, "v": cv, "pos": cpos}
