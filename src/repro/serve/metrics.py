"""Serving observability: per-request lifecycle timestamps + fleet counters.

The engine calls one method per lifecycle edge (submit / reject / admit /
first_token / finish) and ``tick`` once per engine step; ``summary()``
reduces that to the numbers the bench reports — decode throughput, TTFT and
end-to-end latency percentiles, queue depth.  A ``clock`` can be injected
for deterministic tests.

``reset()`` drops per-request state, but requests admitted *before* a reset
finish *after* it (``launch.serve`` resets after warmup with requests in
flight).  Lifecycle edges for such unknown rids are treated as untracked:
completion/token counters still advance, but no percentile sample is
recorded (its submit time belongs to the discarded window) and
``summary()["untracked"]`` counts how many edges were dropped.
"""
from __future__ import annotations

import time


def _pct(xs, q):
    if not xs:
        return None
    import numpy as np
    return float(np.percentile(np.asarray(xs, float), q))


def _ms(seconds):
    return None if seconds is None else seconds * 1e3


class ServeMetrics:
    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self.reset()

    def reset(self) -> None:
        self.t0 = self._clock()
        self.submitted = 0
        self.rejected = 0
        self.admitted = 0
        self.completed = 0
        self.gen_tokens = 0
        self.ticks = 0
        self.max_queue_depth = 0
        self.max_active = 0
        self.ttft = []        # submit -> first token, seconds
        self.latency = []     # submit -> finish, seconds
        self.untracked = 0    # lifecycle edges for rids submitted pre-reset
        self._req = {}        # rid -> {"submit"/"admit"/"first": t}

    # -- lifecycle edges ----------------------------------------------------

    def submit(self, rid) -> None:
        self.submitted += 1
        self._req[rid] = {"submit": self._clock()}

    def reject(self, rid) -> None:
        self.rejected += 1
        self._req.pop(rid, None)

    def admit(self, rid) -> None:
        self.admitted += 1
        r = self._req.get(rid)
        if r is None:
            self.untracked += 1
            return
        r["admit"] = self._clock()

    def first_token(self, rid) -> None:
        r = self._req.get(rid)
        if r is None:
            self.untracked += 1
            return
        r["first"] = self._clock()
        self.ttft.append(r["first"] - r["submit"])

    def finish(self, rid, n_gen: int) -> None:
        # Completion and token-rate counters always advance — the work was
        # done in this window even if the request was submitted before the
        # last reset; only the latency sample is skipped.
        r = self._req.pop(rid, None)
        self.completed += 1
        self.gen_tokens += n_gen
        if r is None:
            self.untracked += 1
            return
        self.latency.append(self._clock() - r["submit"])

    def tick(self, queue_depth: int, active: int) -> None:
        self.ticks += 1
        self.max_queue_depth = max(self.max_queue_depth, queue_depth)
        self.max_active = max(self.max_active, active)

    # -- reduction ----------------------------------------------------------

    def summary(self) -> dict:
        dt = max(self._clock() - self.t0, 1e-9)
        return {
            "submitted": self.submitted,
            "rejected": self.rejected,
            "admitted": self.admitted,
            "completed": self.completed,
            "gen_tokens": self.gen_tokens,
            "ticks": self.ticks,
            "elapsed_s": dt,
            "tokens_per_s": self.gen_tokens / dt,
            "ttft_ms": {"p50": _ms(_pct(self.ttft, 50)),
                        "p99": _ms(_pct(self.ttft, 99))},
            "latency_ms": {"p50": _ms(_pct(self.latency, 50)),
                           "p99": _ms(_pct(self.latency, 99))},
            "max_queue_depth": self.max_queue_depth,
            "max_active": self.max_active,
            "untracked": self.untracked,
        }
