"""Mesh-resident continuous-batching serving engine.

``Engine`` holds params, paged KV pools and SSM state slots on a
``("data", "model")`` mesh and streams requests through jitted batched
prefill + per-tick decode; ``serve.reference.generate`` is the
token-at-a-time differential oracle.
"""
from repro.serve.engine import (Engine, EngineConfig, blocks_needed,
                                stacked_params)
from repro.serve.kvpool import Admission, PagedPool, PoolConfig
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import Request, Scheduler

__all__ = ["Engine", "EngineConfig", "blocks_needed", "stacked_params",
           "Admission", "PagedPool", "PoolConfig", "ServeMetrics",
           "Request", "Scheduler"]
