"""Token-at-a-time reference generator — the serve engine's differential
oracle.

This is the old ``launch/serve.py`` path, kept verbatim on purpose: prefill
is a teacher-forced loop of the *same* jitted single-token ``decode_step``
used for generation, so it exercises none of the engine's machinery (no
paging, no batched prefill, no scheduler) while computing the same greedy
continuation.  Tests compare ``Engine.generate`` output against this
function exactly; the engine defaults its caches to f32 to match the
``dtype`` here (bf16 ring caches vs f32 paged blocks would otherwise differ
in the last bits and occasionally flip an argmax).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import model as M


def generate(cfg, params_stacked, prompts, max_new: int, *,
             max_seq: int = 512, dtype=jnp.float32):
    """prompts (b, p) int32 -> (b, p+max_new) greedy continuation, computed
    one token at a time through ``model.decode_step`` ring caches."""
    b, plen = prompts.shape
    caches = M.init_caches_stacked(cfg, b, max_seq, dtype=dtype)

    @jax.jit
    def step(caches, tok, pos):
        nxt, logits, caches = M.decode_step(
            params_stacked, caches, {"tokens": tok[:, None]}, pos, cfg)
        return caches, nxt, logits

    toks = [prompts[:, i] for i in range(plen)]
    nxt = None
    for pos in range(plen):
        caches, nxt, _ = step(caches, toks[pos], jnp.int32(pos))
    out = list(toks)
    cur = nxt
    for pos in range(plen, plen + max_new):
        out.append(cur)
        caches, cur, _ = step(caches, cur, jnp.int32(pos))
    return jnp.stack(out, axis=1)
