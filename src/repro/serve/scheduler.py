"""Request scheduler: FIFO queue with pool-gated continuous admission.

Each engine tick asks ``take_admissible`` for the next batch of requests to
prefill.  Admission is strictly head-of-line: the scan stops at the first
queued request the pool cannot hold, so a large request is never starved by
smaller ones submitted after it (at the cost of head-of-line blocking — the
simplest policy that keeps completion order fair and the differential tests
deterministic).  ``submit`` applies the queue-depth half of admission
control: a full queue rejects immediately rather than buffering unboundedly.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.serve.kvpool import Admission, PagedPool


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (plen,) int32
    max_new: int
    blocks_needed: int = 0
    status: str = "queued"        # queued | active | done | rejected
    row: int = -1                 # engine row while active
    generated: list = field(default_factory=list)

    @property
    def plen(self) -> int:
        return int(self.prompt.shape[0])

    def tokens(self) -> np.ndarray:
        """prompt + generated, the full served sequence."""
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)])


class Scheduler:
    def __init__(self, max_queue: int):
        self.max_queue = max_queue
        self._q: deque = deque()

    @property
    def depth(self) -> int:
        return len(self._q)

    def submit(self, req: Request) -> bool:
        """Queue a request; False = rejected (queue at ``max_queue``)."""
        if len(self._q) >= self.max_queue:
            return False
        self._q.append(req)
        return True

    def take_admissible(self, pool: PagedPool,
                        limit: int) -> List[Tuple[Request, Admission]]:
        """Pop up to ``limit`` head-of-line requests that fit the pool right
        now, admitting each (rows/blocks are consumed as they are popped)."""
        out = []
        while self._q and len(out) < limit:
            req = self._q[0]
            if pool.can_admit(req.blocks_needed) is None:
                break
            self._q.popleft()
            out.append((req, pool.admit(req.blocks_needed)))
        return out
