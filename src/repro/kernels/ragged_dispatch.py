"""Pallas TPU ragged-dispatch kernel for MoE token routing.

The dense dispatch (``units._dispatch``) scatter-adds every (token, top-k)
slot into the (E, C, d) capacity buffer — XLA lowers that to a full
scatter over E*C*d even though at most ``min(s*k, E*C)`` rows are live.
The ragged form inverts the routing on the slot side first: a slot map
``src (E*C,) int32`` holds the flat token index that owns each capacity
slot (-1 for empty slots — experts are *ragged*, each fills only as many
slots as tokens routed to it).  The kernel is then a pure row gather with
one DMA'd x-row per occupied slot, streamed block-by-block via scalar
prefetch (the slot map is prefetched to SMEM so each grid step's BlockSpec
can pick its source row dynamically), and empty slots write zeros without
touching HBM bandwidth for x.

Capacity-overflow determinism: the slot map is built from the same
scan-order cumsum routing as the dense path, so which tokens drop (and
therefore which slots stay empty) is bitwise identical to the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def build_slot_map(idx, pos, keep, E: int, C: int):
    """Invert routing decisions to the slot side.

    idx/pos (n, k) int32, keep (n, k) {0,1} for n flat tokens ->
    src (E*C,) int32: the flat token index owning slot e*C+c, or -1 if the
    slot is empty.  Kept slots are unique by construction (``pos`` is a
    per-expert running count), so the scatter has no collisions.
    """
    n, k = idx.shape
    flat_slot = (idx * C + pos).reshape(-1)
    tok = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    kept = keep.reshape(-1) > 0
    # dropped (token, k) slots scatter out of bounds and are discarded
    target = jnp.where(kept, flat_slot, E * C)
    return (jnp.full((E * C,), -1, jnp.int32)
            .at[target].set(tok, mode="drop"))


def _gather_kernel(src_ref, x_ref, o_ref):
    i = pl.program_id(0)
    valid = (src_ref[i] >= 0).astype(o_ref.dtype)
    o_ref[...] = x_ref[...] * valid


@functools.partial(jax.jit, static_argnames=("E", "C", "interpret"))
def ragged_dispatch_fwd(x, src, E: int, C: int, interpret: bool = True):
    """x (n, d), src (E*C,) -> expert_in (E, C, d).

    Row r of the output is ``x[src[r]]`` for occupied slots and zeros for
    empty ones.  The slot map rides the scalar-prefetch channel so the x
    BlockSpec resolves its source row before the block DMA issues
    (negative entries clamp to row 0 and are masked in-kernel).
    """
    n, d = x.shape
    pad = (-d) % 128
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(E * C,),
            in_specs=[pl.BlockSpec((1, d + pad),
                                   lambda i, src: (jnp.maximum(src[i], 0), 0))],
            out_specs=pl.BlockSpec((1, d + pad), lambda i, src: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((E * C, d + pad), x.dtype),
        interpret=interpret,
    )(src, x)
    return out[:, :d].reshape(E, C, d)
