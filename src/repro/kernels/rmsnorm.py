"""Pallas TPU fused RMSNorm kernel.

Pre-Attn / Pre-MLP units are memory-bound (read x, write x_ln); fusing the
mean-square reduction, rsqrt and gain multiply into one VMEM pass halves the
HBM traffic vs the unfused jnp graph.  Rows tile in blocks of ``rb`` (8*k
sublanes), the model dim stays resident (d <= a few K fits VMEM easily).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # (rb, d)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)
                  * g_ref[...].astype(jnp.float32)[None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "rb", "interpret"))
def rmsnorm_fwd(x, g, eps: float = 1e-6, rb: int = 256,
                interpret: bool = True):
    """x (..., d), g (d,) -> rmsnorm(x) * g, fused."""
    shape = x.shape
    d = shape[-1]
    x2 = x.reshape(-1, d)
    n = x2.shape[0]
    rb = min(rb, max(8, n))
    pad = (-n) % rb
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(x2.shape[0] // rb,),
        in_specs=[pl.BlockSpec((rb, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, g)
    return out[:n].reshape(shape)
