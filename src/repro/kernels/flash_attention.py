"""Pallas TPU flash-attention forward kernel.

The paper runs FlashAttention-2 in every experiment (§5.1); on TPU the
algorithm is re-blocked for the MXU/VMEM hierarchy instead of CUDA warps:

  * grid = (batch*kv_heads, q_blocks, kv_blocks); the kv dimension is the
    innermost ("arbitrary") axis so the online-softmax accumulators live in
    VMEM scratch across kv iterations,
  * BlockSpec tiles: q/o (1, qb, d), k/v (1, kb, d) — qb/kb default 128/256,
    multiples of the 128-lane MXU tiling; fp32 accumulation regardless of
    input dtype,
  * causal and sliding-window (gemma3 local layers) masks computed from
    absolute positions, GQA folded outside the kernel (q heads of one kv
    group concatenate into the q rows — the kernel sees plain MHA).

Validated against ``repro.kernels.ref.reference_attention`` in
``interpret=True`` mode on CPU (this container's runtime); on a real TPU the
same ``pallas_call`` lowers to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                      scale: float, causal: bool, window: Optional[int],
                      qb: int, kb: int, seq_q: int, seq_k: int,
                      q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                   # (qb, d)
    k = k_ref[0].astype(jnp.float32)                   # (kb, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0) \
        + q_offset
    kpos = ki * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
    mask = (kpos < seq_k) & (qpos < seq_q + q_offset)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        l_safe = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)


def _pad_axis(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "q_offset", "qb", "kb",
                                             "interpret"))
def flash_attention_fwd(q, k, v, causal: bool = True,
                        window: Optional[int] = None,
                        scale: Optional[float] = None, q_offset: int = 0,
                        qb: int = 128, kb: int = 256,
                        interpret: bool = True):
    """q (BH, S, D); k, v (BH, T, D) — MHA layout (GQA folded by ops.py)."""
    BH, S, D = q.shape
    T = k.shape[1]
    scale = scale if scale is not None else D ** -0.5
    qb = min(qb, max(16, S))
    kb = min(kb, max(16, T))
    qp = _pad_axis(q, qb, 1)
    kp = _pad_axis(k, kb, 1)
    vp = _pad_axis(v, kb, 1)
    nq, nk = qp.shape[1] // qb, kp.shape[1] // kb

    kern = functools.partial(
        _flash_fwd_kernel, scale=scale, causal=causal, window=window,
        qb=qb, kb=kb, seq_q=S, seq_k=T, q_offset=q_offset)
    out = pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qb, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kb, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kb, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, D), jnp.float32),     # acc
            pltpu.VMEM((qb,), jnp.float32),       # running max m
            pltpu.VMEM((qb,), jnp.float32),       # running sum l
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qp, kp, vp)
    return out[:, :S]
