"""Pallas collective-matmul: row-parallel matmul fused with its all-reduce
contribution.

Every row-parallel projection (wo / wd / w2 / w_out / w_down) ends its unit
with ``psum(x @ w)``.  Decomposed over a ring (the ``TPContext.ring_psum``
schedule), hop ``s`` of the reduce-scatter must compute the local partial of
one output-feature tile and fold it into the partial just received from the
ring neighbour: ``acc + x @ w_tile``.  That is exactly one fused kernel —
the matmul epilogue accumulates the ring contribution while the output tile
is still in VMEM, so the per-hop accumulate costs no extra HBM round-trip.

``matmul_psum_step`` is that per-hop kernel (MXU-tiled over (M, N, K)
blocks, fp32 accumulator initialised from ``acc`` on the first K step);
``collective_matmul_allreduce`` drives it around the ring: ``t-1`` fused
reduce-scatter hops followed by a ``t-1``-hop all-gather of the owned
tiles, matching ``lax.psum(x @ w)`` bitwise at ``t <= 2`` and up to ring
reassociation beyond.  Oracle: ``ref.reference_matmul_psum_step``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.tp.context import TPContext


def _matmul_acc_kernel(x_ref, w_ref, acc_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = acc_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                          w_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul_psum_step(x, w, acc, bm: int = 128, bn: int = 128, bk: int = 128,
                     interpret: bool = True):
    """One fused ring hop: ``x (m, k) @ w (k, n) + acc (m, n)`` in fp32.

    The accumulator block initialises the output tile at the first K step,
    so the ring partial rides the matmul epilogue instead of a separate
    elementwise pass.  Returns fp32 (the ring carries full precision; the
    caller casts once after the all-gather).
    """
    m, k = x.shape
    n = w.shape[1]
    assert w.shape[0] == k and acc.shape == (m, n), (x.shape, w.shape,
                                                     acc.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    if pm or pn:
        acc = jnp.pad(acc, ((0, pm), (0, pn)))
    out = pl.pallas_call(
        _matmul_acc_kernel,
        grid=(x.shape[0] // bm, w.shape[1] // bn, x.shape[1] // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
                  pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
                  pl.BlockSpec((bm, bn), lambda i, j, s: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], w.shape[1]),
                                       jnp.float32),
        interpret=interpret,
    )(x, w, acc)
    return out[:m, :n]


def collective_matmul_allreduce(x, w, tp: TPContext, *,
                                interpret: Optional[bool] = None):
    """Ring-decomposed ``tp.psum(x @ w)`` with fused per-hop accumulates.

    x (..., k_local) and w (k_local, n) are the per-rank shards of a
    row-parallel projection; returns the fully all-reduced (..., n) product
    on every rank.  Falls back to kernel-matmul + monolithic psum when
    there is no ring (``size <= 1``) or ``n`` does not tile by ``size``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    lead, n = x.shape[:-1], w.shape[1]
    x2 = x.reshape(-1, x.shape[-1])
    t = tp.size

    def out_of(full):
        return full.reshape(lead + (n,)).astype(x.dtype)

    if tp.axis is None or t == 1 or n % t:
        z = jnp.zeros((x2.shape[0], n), jnp.float32)
        return out_of(tp.psum(matmul_psum_step(x2, w, z,
                                               interpret=interpret)))

    cn = n // t
    r = jax.lax.axis_index(tp.axis)
    perm = [(i, (i + 1) % t) for i in range(t)]

    def wtile(i):
        return jax.lax.dynamic_slice_in_dim(w, (i % t) * cn, cn, axis=1)

    # reduce-scatter: after hop s, rank r holds the partial of output tile
    # (r - s) % t over ranks {r-s..r}; after t-1 hops it owns tile (r+1)%t.
    z = jnp.zeros((x2.shape[0], cn), jnp.float32)
    acc = matmul_psum_step(x2, wtile(r), z, interpret=interpret)
    for s in range(1, t):
        acc = matmul_psum_step(x2, wtile(r - s),
                               jax.lax.ppermute(acc, tp.axis, perm),
                               interpret=interpret)
    # all-gather the owned tiles the rest of the way round the ring.
    out = jnp.zeros((t, x2.shape[0], cn), jnp.float32)
    out = jax.lax.dynamic_update_index_in_dim(out, acc, (r + 1) % t, 0)
    buf = acc
    for s in range(1, t):
        buf = jax.lax.ppermute(buf, tp.axis, perm)
        out = jax.lax.dynamic_update_index_in_dim(out, buf,
                                                  (r - s + 1) % t, 0)
    return out_of(jnp.concatenate([out[i] for i in range(t)], axis=-1))
