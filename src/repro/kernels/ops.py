"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only: the
kernel bodies execute in Python for correctness validation); on a TPU
backend the same calls lower to Mosaic.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.collective_matmul import (collective_matmul_allreduce,
                                             matmul_psum_step)
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ragged_dispatch import build_slot_map, ragged_dispatch_fwd
from repro.kernels.rmsnorm import rmsnorm_fwd
from repro.tp.context import TPContext


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None, q_offset: int = 0,
                    qb: int = 128, kb: int = 256,
                    interpret: Optional[bool] = None):
    """GQA entry point: q (B, Hq, S, D); k, v (B, Hkv, T, D).

    Folds the q heads of each kv group into the row dimension (positions
    repeat per group, handled by ``q_offset`` masking inside the kernel
    only when S == T; grouped-fold with distinct positions delegates to a
    per-group vmap)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = q.reshape(B, Hkv, G, S, D).reshape(B * Hkv, G, S, D)
    kf = k.reshape(B * Hkv, T, D)
    vf = v.reshape(B * Hkv, T, D)

    def per_group(qg):
        return flash_attention_fwd(qg, kf, vf, causal=causal, window=window,
                                   scale=scale, q_offset=q_offset, qb=qb,
                                   kb=kb, interpret=interpret)

    o = jax.vmap(per_group, in_axes=1, out_axes=1)(qf)   # (B*Hkv, G, S, D)
    return o.reshape(B, Hkv, G, S, D).reshape(B, Hq, S, D)


def rmsnorm(x, g, eps: float = 1e-6, interpret: Optional[bool] = None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return rmsnorm_fwd(x, g, eps=eps, interpret=interpret)


def collective_matmul(x, w, tp: TPContext,
                      interpret: Optional[bool] = None):
    """Row-parallel ``psum(x @ w)`` as a fused ring of matmul+accumulate
    hops (``kernels.collective_matmul``)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return collective_matmul_allreduce(x, w, tp, interpret=interpret)


def ragged_dispatch(x, idx, pos, keep, E: int, C: int,
                    interpret: Optional[bool] = None):
    """Capacity-bucketed MoE token dispatch x (b, s, d) -> (b, E, C, d)
    through the ragged gather kernel (``kernels.ragged_dispatch``): the
    routing decisions are inverted into a per-slot source map, then each
    occupied capacity slot pulls exactly one token row.  Matches the dense
    scatter-add (``ref.reference_ragged_dispatch``) bitwise, including
    which tokens drop on capacity overflow."""
    interpret = (not _on_tpu()) if interpret is None else interpret

    def one(xr, ir, pr, kr):
        src = build_slot_map(ir, pr, kr, E, C)
        return ragged_dispatch_fwd(xr, src, E, C, interpret=interpret)

    return jax.vmap(one)(x, idx, pos, keep)


def matmul_accumulate(x, w, acc, interpret: Optional[bool] = None):
    """One fused ring hop ``x @ w + acc`` (fp32), the building block of
    ``collective_matmul``."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return matmul_psum_step(x, w, acc, interpret=interpret)
