"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.attention_core import reference_attention  # noqa: F401


def reference_attention_mha(q, k, v, causal: bool = True,
                            window: Optional[int] = None,
                            scale: Optional[float] = None,
                            q_offset: int = 0):
    """(BH, S, D) MHA layout oracle matching the kernel's folded view."""
    o = reference_attention(q[:, None], k[:, None], v[:, None],
                            causal=causal, window=window, scale=scale,
                            q_offset=q_offset)
    return o[:, 0]


def reference_rmsnorm(x, g, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)
            * g.astype(jnp.float32)).astype(x.dtype)


def reference_matmul_psum_step(x, w, acc):
    """Oracle for one fused ring hop: fp32 ``x @ w + acc``."""
    return (jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
            + acc.astype(jnp.float32))


def reference_ragged_dispatch(x, idx, pos, keep, E: int, C: int):
    """Dense scatter-add oracle for the ragged-dispatch gather: x (n, d)
    with routing idx/pos/keep (n, k) -> expert_in (E, C, d).  Dropped
    slots (keep == 0) contribute nothing; kept slots are unique."""
    n, d = x.shape
    k = idx.shape[-1]
    flat = (idx * C + pos).reshape(n * k)
    upd = (x[:, None, :] * keep[..., None].astype(x.dtype)).reshape(n * k, d)
    return (jnp.zeros((E * C, d), x.dtype).at[flat].add(upd)
            .reshape(E, C, d))
