from repro.optim.adamw import (OptConfig, adamw_init, adamw_leaf,
                               adamw_scalars, adamw_update,
                               clip_by_global_norm, lr_at)
