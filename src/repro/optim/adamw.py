"""AdamW with global-norm clipping and warmup+cosine/linear LR schedules.

Pure-pytree implementation (no optax dependency): states shard exactly like
their parameters under pjit, which the dry-run relies on for the ZeRO-style
``data``-axis optimizer sharding.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Literal

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: Literal["cosine", "linear", "const"] = "cosine"
    min_lr_frac: float = 0.1


def lr_at(oc: OptConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(oc.warmup_steps, 1))
    t = jnp.clip((step - oc.warmup_steps)
                 / max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    if oc.schedule == "cosine":
        decay = oc.min_lr_frac + (1 - oc.min_lr_frac) \
            * 0.5 * (1 + jnp.cos(jnp.pi * t))
    elif oc.schedule == "linear":
        decay = oc.min_lr_frac + (1 - oc.min_lr_frac) * (1 - t)
    else:
        decay = 1.0
    return oc.lr * warm * decay


def adamw_init(params):
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x), p)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gn


def _is_matrix(x):
    return x.ndim >= 2


def adamw_scalars(oc: OptConfig, step_prev):
    """(lr, bias-correction c1, c2) for the update taken *from* step_prev.

    Shared by the host path and the in-mesh (shard_map) path in
    ``repro.pipeline.spmd`` so their numerics agree bit-for-formula."""
    step = step_prev + 1
    b1, b2 = oc.betas
    lr = lr_at(oc, step_prev)
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)
    return lr, c1, c2


def adamw_leaf(p, g, mu, nu, lr, c1, c2, oc: OptConfig, decay: bool):
    """One already-clipped-gradient AdamW leaf update."""
    b1, b2 = oc.betas
    g = g.astype(jnp.float32)
    mu = b1 * mu + (1 - b1) * g
    nu = b2 * nu + (1 - b2) * jnp.square(g)
    mhat = mu / c1
    nhat = nu / c2
    delta = mhat / (jnp.sqrt(nhat) + oc.eps)
    if decay:
        delta = delta + oc.weight_decay * p.astype(jnp.float32)
    return (p - lr * delta).astype(p.dtype), mu, nu


def adamw_update(params, grads, state, oc: OptConfig, *, decay_mask=None):
    """Host AdamW step.  ``decay_mask`` (optional bool pytree matching
    ``params``) marks which leaves get weight decay; by default every
    rank>=2 leaf does, which is only correct for *canonical* (unstacked)
    layouts — stacked layouts must supply the mask
    (``repro.launch.state.decay_mask``)."""
    grads, gnorm = clip_by_global_norm(grads, oc.clip_norm)
    step = state["step"] + 1
    lr, c1, c2 = adamw_scalars(oc, state["step"])

    p_flat, treedef = jax.tree.flatten(params)
    g_flat = treedef.flatten_up_to(grads)
    mu_flat = treedef.flatten_up_to(state["mu"])
    nu_flat = treedef.flatten_up_to(state["nu"])
    d_flat = ([_is_matrix(p) for p in p_flat] if decay_mask is None
              else treedef.flatten_up_to(decay_mask))
    out = [adamw_leaf(p, g, mu, nu, lr, c1, c2, oc, d)
           for p, g, mu, nu, d
           in zip(p_flat, g_flat, mu_flat, nu_flat, d_flat)]
    unflat = lambda i: jax.tree.unflatten(treedef, [o[i] for o in out])
    return unflat(0), {"mu": unflat(1), "nu": unflat(2), "step": step}, gnorm
