"""Public training API surface.

    from repro.api import (TrainState, Layout, Runner, PjitRunner,
                           ReferenceRunner, SpmdRunner, make_runner,
                           save_state, load_state)

One ``TrainState`` pytree (layout-resident params + AdamW moments + step)
and one ``runner.step(state, batch) -> (state, metrics)`` loop cover all
three runtimes; checkpoints are canonical-layout and runtime-portable.
See docs/API.md.
"""
from repro.launch.runner import (PjitRunner, ReferenceRunner, Runner,
                                 SpmdRunner, make_runner)
from repro.launch.state import (Layout, TrainState, decay_mask,
                                load_canonical, load_state, save_state)

__all__ = ["TrainState", "Layout", "decay_mask", "Runner", "PjitRunner",
           "ReferenceRunner", "SpmdRunner", "make_runner", "save_state",
           "load_state", "load_canonical"]
