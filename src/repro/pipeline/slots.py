"""Lockstep-slot conversion + placement-generic branch encoding.

The event-driven schedule is asynchronous; the SPMD executor runs one
instruction per device per *slot* with a ``ppermute`` exchange at every slot
boundary.  ``to_slots`` assigns each instruction its wavefront level —
max(own device's previous slot, every dependency's slot) + 1 — which
preserves program order and guarantees all cross-device inputs arrived in an
earlier slot's exchange.  This lowering is placement-independent: every
cross-stage hop (including the parallel placement's chunk-0 -> chunk-1
wrap-around from the last device back to device 0) is a single neighbour
exchange on the stage ring.

``encode`` then maps each instruction component to a *branch role* — which
``lax.switch`` arm the executor must run for it.  The role tables differ per
placement because the embed, loss-head and chunk-turn stages land on
different devices:

  flat      v=1: embed on device 0, loss head on device p-1; activations
            flow +1, gradients -1.  Chunk 1 does not exist.
  parallel  v=2 (1F1B-I): chunk c stage s on device s; both chunks'
            activations flow +1 *with wrap-around* (vs p-1 on device p-1
            hands off to vs p on device 0), gradients -1 with wrap.
  vshape    v=2 (ZB-V / STP): chunk 0 ascends, chunk 1 descends; the turn
            and the loss head are device-local writes on devices p-1 / 0.
"""
from __future__ import annotations

import numpy as np

from repro.core.simulator import Instr, Placement, instr_dep_keys

# Branch-role vocabularies per placement kind.  Index in the tuple == the
# int32 code emitted by ``encode`` == the ``lax.switch`` arm the executor
# builds for that role (role "*_nop" is always code 0).
F_BRANCHES = {
    "flat": ("f_nop", "f0", "f0_embed", "f0_loss"),
    "parallel": ("f_nop", "f0", "f0_embed", "f0_send1", "f1", "f1_loss"),
    "vshape": ("f_nop", "f0", "f0_embed", "f0_turn", "f1", "f1_loss"),
}
B_BRANCHES = {
    "flat": ("b_nop", "b0", "b0_embed", "b0_loss"),
    "parallel": ("b_nop", "b0", "b0_embed", "b1", "b1_send0", "b1_loss"),
    "vshape": ("b_nop", "b0", "b0_embed", "b1", "b1_turn", "b1_loss"),
}
W_BRANCHES = {
    "flat": ("w_nop", "w0", "w0_head"),
    "parallel": ("w_nop", "w0", "w1", "w1_head"),
    "vshape": ("w_nop", "w0", "w1", "w1_head"),
}

# Stream wiring: which of the four boundary streams (x0/x1 activations,
# g0/g1 gradients, by *destination* buffer) ride the +1 ("up") vs the -1
# ("dn") exchange, and whether the ring wraps.
WIRING = {
    "flat": dict(up=("x0",), dn=("g0",), wrap=False),
    "parallel": dict(up=("x0", "x1"), dn=("g0", "g1"), wrap=True),
    "vshape": dict(up=("x0", "g1"), dn=("x1", "g0"), wrap=False),
}


def f_role(pl: Placement, vs: int, d: int) -> str:
    p = pl.p
    if pl.kind == "flat":
        if d == 0:
            return "f0_embed"
        return "f0_loss" if d == p - 1 else "f0"
    if pl.chunk(vs) == 0:
        if d == 0:
            return "f0_embed"
        if vs == p - 1:  # last chunk-0 stage: output enters chunk 1
            return "f0_turn" if pl.kind == "vshape" else "f0_send1"
        return "f0"
    return "f1_loss" if vs == pl.n_vs - 1 else "f1"


def b_role(pl: Placement, vs: int, d: int) -> str:
    p = pl.p
    if pl.kind == "flat":
        if d == p - 1:
            return "b0_loss"
        return "b0_embed" if d == 0 else "b0"
    if pl.chunk(vs) == 0:
        return "b0_embed" if d == 0 else "b0"
    if vs == pl.n_vs - 1:
        return "b1_loss"
    if vs == p:          # lowest chunk-1 stage: gradient enters chunk 0
        return "b1_turn" if pl.kind == "vshape" else "b1_send0"
    return "b1"


def w_role(pl: Placement, vs: int, d: int) -> str:
    if pl.kind == "flat":
        return "w0_head" if d == pl.p - 1 else "w0"
    if pl.chunk(vs) == 0:
        return "w0"
    return "w1_head" if vs == pl.n_vs - 1 else "w1"


def to_slots(tables, pl: Placement):
    """-> list per device of list per slot of Optional[Instr]."""
    p, n_vs = pl.p, pl.n_vs
    level: dict = {}
    dev_level = [-1] * p
    ptr = [0] * p
    slotted: list[list] = [[] for _ in range(p)]
    remaining = sum(len(t) for t in tables)
    while remaining:
        progressed = False
        for d in range(p):
            if ptr[d] >= len(tables[d]):
                continue
            ins = tables[d][ptr[d]]
            deps = []
            ok = True
            for key, tag in instr_dep_keys(ins, n_vs):
                if key not in level:
                    ok = False
                    break
                # a "tape" dep is a locally-stored W input: program order on
                # this device already sequences it, so same-slot is legal.
                deps.append(level[key] - (1 if tag == "tape" else 0))
            if not ok:
                continue
            slot = max([dev_level[d]] + [x for x in deps]) + 1
            for ph, vs, mb in ins.components():
                level[(ph, vs, mb)] = slot
            dev_level[d] = slot
            slotted[d].append((slot, ins))
            ptr[d] += 1
            remaining -= 1
            progressed = True
        if not progressed:
            raise RuntimeError("slot conversion stalled")
    n_slots = max(dev_level) + 1
    grid = [[None] * n_slots for _ in range(p)]
    for d in range(p):
        for slot, ins in slotted[d]:
            grid[d][slot] = ins
    return grid


def encode(grid, pl: Placement) -> np.ndarray:
    """-> int32 codes of shape (n_slots, p, 6):
    [f_code, f_mb, b_code, b_mb, w_code, w_mb], indices into the
    placement's F_BRANCHES / B_BRANCHES / W_BRANCHES vocabularies."""
    p = pl.p
    fb, bb, wb = F_BRANCHES[pl.kind], B_BRANCHES[pl.kind], W_BRANCHES[pl.kind]
    n_slots = len(grid[0])
    codes = np.zeros((n_slots, p, 6), np.int32)
    for d in range(p):
        for t, ins in enumerate(grid[d]):
            if ins is None:
                continue
            if ins.f is not None:
                codes[t, d, 0] = fb.index(f_role(pl, ins.f[0], d))
                codes[t, d, 1] = ins.f[1]
            if ins.b is not None:
                codes[t, d, 2] = bb.index(b_role(pl, ins.b[0], d))
                codes[t, d, 3] = ins.b[1]
            if ins.w is not None:
                codes[t, d, 4] = wb.index(w_role(pl, ins.w[0], d))
                codes[t, d, 5] = ins.w[1]
    # p == 1 cannot happen (p >= 2 enforced by caller)
    return codes
