"""Lockstep-slot conversion of schedule tables.

The event-driven schedule is asynchronous; the SPMD executor runs one
instruction per device per *slot* with a ``ppermute`` exchange at every slot
boundary.  ``to_slots`` assigns each instruction its wavefront level —
max(own device's previous slot, every dependency's slot) + 1 — which
preserves program order and guarantees all cross-device inputs arrived in an
earlier slot's exchange.
"""
from __future__ import annotations

import numpy as np

from repro.core.simulator import Instr, Placement

NOP = Instr("W", w=None)  # placeholder; encoded as all-zero codes

# f codes
F_NOP, F0, F0_EMBED, F0_TURN, F1, F1_LOSS = range(6)
# b codes
B_NOP, B0, B0_EMBED, B1, B1_TURN, B1_LOSS = range(6)
# w codes
W_NOP, W0, W1, W1_HEAD = range(4)


def to_slots(tables, pl: Placement):
    """-> list per device of list per slot of Optional[Instr]."""
    p, n_vs = pl.p, pl.n_vs
    level: dict = {}
    dev_level = [-1] * p
    ptr = [0] * p
    slotted: list[list] = [[] for _ in range(p)]
    remaining = sum(len(t) for t in tables)
    while remaining:
        progressed = False
        for d in range(p):
            if ptr[d] >= len(tables[d]):
                continue
            ins = tables[d][ptr[d]]
            deps = []
            ok = True
            if ins.f is not None:
                vs, mb = ins.f
                if vs > 0:
                    key = ("F", vs - 1, mb)
                    if key not in level:
                        ok = False
                    else:
                        deps.append(level[key])
            if ok and ins.b is not None:
                vs, mb = ins.b
                if vs < n_vs - 1:
                    key = ("B", vs + 1, mb)
                    if key not in level:
                        ok = False
                    else:
                        deps.append(level[key])
                elif ins.f != (vs, mb):
                    key = ("F", vs, mb)
                    if key not in level:
                        ok = False
                    else:
                        deps.append(level[key])
            if ok and ins.w is not None and ins.w != ins.b:
                key = ("B", *ins.w)
                if key not in level:
                    ok = False
                else:
                    # W consumes a locally-stored tape: no +1 needed, but
                    # program order already enforces it on this device.
                    deps.append(level[key] - 1)
            if not ok:
                continue
            slot = max([dev_level[d]] + [x for x in deps]) + 1
            for ph, vs, mb in ins.components():
                level[(ph, vs, mb)] = slot
            dev_level[d] = slot
            slotted[d].append((slot, ins))
            ptr[d] += 1
            remaining -= 1
            progressed = True
        if not progressed:
            raise RuntimeError("slot conversion stalled")
    n_slots = max(dev_level) + 1
    grid = [[None] * n_slots for _ in range(p)]
    for d in range(p):
        for slot, ins in slotted[d]:
            grid[d][slot] = ins
    return grid


def encode(grid, pl: Placement) -> np.ndarray:
    """-> int32 codes of shape (n_slots, p, 6):
    [f_code, f_mb, b_code, b_mb, w_code, w_mb]."""
    p = pl.p
    n_slots = len(grid[0])
    codes = np.zeros((n_slots, p, 6), np.int32)

    def fc(vs, d):
        if pl.chunk(vs) == 0:
            if d == 0:
                return F0_EMBED
            return F0_TURN if d == p - 1 else F0
        return F1_LOSS if d == 0 else F1

    def bc(vs, d):
        if pl.chunk(vs) == 0:
            return B0_EMBED if d == 0 else B0
        if d == 0:
            return B1_LOSS
        return B1_TURN if d == p - 1 else B1

    def wc(vs, d):
        if pl.chunk(vs) == 0:
            return W0
        return W1_HEAD if d == 0 else W1

    for d in range(p):
        for t, ins in enumerate(grid[d]):
            if ins is None:
                continue
            if ins.f is not None:
                codes[t, d, 0] = fc(ins.f[0], d)
                codes[t, d, 1] = ins.f[1]
            if ins.b is not None:
                codes[t, d, 2] = bc(ins.b[0], d)
                codes[t, d, 3] = ins.b[1]
            if ins.w is not None:
                codes[t, d, 4] = wc(ins.w[0], d)
                codes[t, d, 5] = ins.w[1]
    # special case p-1 == 0 cannot happen (p >= 2 enforced by caller)
    return codes
