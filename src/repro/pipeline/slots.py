"""Lockstep-slot conversion + placement-generic branch encoding.

The event-driven schedule is asynchronous; the SPMD executor runs one
instruction per device per *slot* with a ``ppermute`` exchange at every slot
boundary.  ``to_slots`` assigns each instruction its wavefront level —
max(own device's previous slot, every dependency's slot) + 1 — which
preserves program order and guarantees all cross-device inputs arrived in an
earlier slot's exchange.  This lowering is placement-independent: every
cross-stage hop (including the parallel placement's chunk-0 -> chunk-1
wrap-around from the last device back to device 0) is a single neighbour
exchange on the stage ring.

``encode`` then maps each instruction component to a *branch role* — which
``lax.switch`` arm the executor must run for it.  The role tables differ per
placement because the embed, loss-head and chunk-turn stages land on
different devices:

  flat      v=1: embed on device 0, loss head on device p-1; activations
            flow +1, gradients -1.  Chunk 1 does not exist.
  parallel  v=2 (1F1B-I): chunk c stage s on device s; both chunks'
            activations flow +1 *with wrap-around* (vs p-1 on device p-1
            hands off to vs p on device 0), gradients -1 with wrap.
  vshape    v=2 (ZB-V / STP): chunk 0 ascends, chunk 1 descends; the turn
            and the loss head are device-local writes on devices p-1 / 0.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.simulator import Instr, OffloadOp, Placement, instr_dep_keys

# Branch-role vocabularies per placement kind.  Index in the tuple == the
# int32 code emitted by ``encode`` == the ``lax.switch`` arm the executor
# builds for that role (role "*_nop" is always code 0).
F_BRANCHES = {
    "flat": ("f_nop", "f0", "f0_embed", "f0_loss"),
    "parallel": ("f_nop", "f0", "f0_embed", "f0_send1", "f1", "f1_loss"),
    "vshape": ("f_nop", "f0", "f0_embed", "f0_turn", "f1", "f1_loss"),
}
B_BRANCHES = {
    "flat": ("b_nop", "b0", "b0_embed", "b0_loss"),
    "parallel": ("b_nop", "b0", "b0_embed", "b1", "b1_send0", "b1_loss"),
    "vshape": ("b_nop", "b0", "b0_embed", "b1", "b1_turn", "b1_loss"),
}
W_BRANCHES = {
    "flat": ("w_nop", "w0", "w0_head"),
    "parallel": ("w_nop", "w0", "w1", "w1_head"),
    "vshape": ("w_nop", "w0", "w1", "w1_head"),
}

# Stream wiring: which of the four boundary streams (x0/x1 activations,
# g0/g1 gradients, by *destination* buffer) ride the +1 ("up") vs the -1
# ("dn") exchange, and whether the ring wraps.
WIRING = {
    "flat": dict(up=("x0",), dn=("g0",), wrap=False),
    "parallel": dict(up=("x0", "x1"), dn=("g0", "g1"), wrap=True),
    "vshape": dict(up=("x0", "g1"), dn=("x1", "g0"), wrap=False),
}

# Which boundary stream (if any) each branch role *emits* into.  Roles not
# listed are device-local (turn/loss/embed-grad) or nops.  This is the
# static-liveness table behind the fused lowering's ppermute elision: a
# stream is dead in a slot segment iff no device's role emits into it.
EMITS = {
    "f0": "x0", "f0_embed": "x0",     # chunk-0 activation, +1 hop
    "f0_send1": "x1", "f1": "x1",     # chunk-1 activation (wrap / +-1 hop)
    "b0": "g0", "b0_loss": "g0",      # chunk-0 gradient, -1 hop
    "b1_send0": "g0",                 # parallel wrap back into chunk 0
    "b1": "g1", "b1_loss": "g1",      # chunk-1 gradient
}

# Which mb column of the 6-wide code row carries the emitting phase's
# microbatch index, per stream: activations travel with the sender's F-mb,
# gradients with the sender's B-mb.
_MB_COL = {"x0": 1, "x1": 1, "g0": 3, "g1": 3}
_ROLE_COL = {"x0": 0, "x1": 0, "g0": 2, "g1": 2}


def f_role(pl: Placement, vs: int, d: int) -> str:
    p = pl.p
    if pl.kind == "flat":
        if d == 0:
            return "f0_embed"
        return "f0_loss" if d == p - 1 else "f0"
    if pl.chunk(vs) == 0:
        if d == 0:
            return "f0_embed"
        if vs == p - 1:  # last chunk-0 stage: output enters chunk 1
            return "f0_turn" if pl.kind == "vshape" else "f0_send1"
        return "f0"
    return "f1_loss" if vs == pl.n_vs - 1 else "f1"


def b_role(pl: Placement, vs: int, d: int) -> str:
    p = pl.p
    if pl.kind == "flat":
        if d == p - 1:
            return "b0_loss"
        return "b0_embed" if d == 0 else "b0"
    if pl.chunk(vs) == 0:
        return "b0_embed" if d == 0 else "b0"
    if vs == pl.n_vs - 1:
        return "b1_loss"
    if vs == p:          # lowest chunk-1 stage: gradient enters chunk 0
        return "b1_turn" if pl.kind == "vshape" else "b1_send0"
    return "b1"


def w_role(pl: Placement, vs: int, d: int) -> str:
    if pl.kind == "flat":
        return "w0_head" if d == pl.p - 1 else "w0"
    if pl.chunk(vs) == 0:
        return "w0"
    return "w1_head" if vs == pl.n_vs - 1 else "w1"


def to_slots(tables, pl: Placement):
    """-> list per device of list per slot of Optional[Instr]."""
    p, n_vs = pl.p, pl.n_vs
    level: dict = {}
    dev_level = [-1] * p
    ptr = [0] * p
    slotted: list[list] = [[] for _ in range(p)]
    remaining = sum(len(t) for t in tables)
    while remaining:
        progressed = False
        for d in range(p):
            if ptr[d] >= len(tables[d]):
                continue
            ins = tables[d][ptr[d]]
            deps = []
            ok = True
            for key, tag in instr_dep_keys(ins, n_vs):
                if key not in level:
                    ok = False
                    break
                # a "tape" dep is a locally-stored W input: program order on
                # this device already sequences it, so same-slot is legal.
                deps.append(level[key] - (1 if tag == "tape" else 0))
            if not ok:
                continue
            slot = max([dev_level[d]] + [x for x in deps]) + 1
            for ph, vs, mb in ins.components():
                level[(ph, vs, mb)] = slot
            dev_level[d] = slot
            slotted[d].append((slot, ins))
            ptr[d] += 1
            remaining -= 1
            progressed = True
        if not progressed:
            lines = []
            for d in range(p):
                if ptr[d] >= len(tables[d]):
                    lines.append(f"  device {d}: done ({ptr[d]} instrs)")
                    continue
                ins = tables[d][ptr[d]]
                missing = [key for key, _ in instr_dep_keys(ins, n_vs)
                           if key not in level]
                lines.append(f"  device {d}: ptr={ptr[d]}/{len(tables[d])} "
                             f"pending {ins} missing deps {missing}")
            raise RuntimeError(
                "slot conversion stalled — some instruction's dependency is "
                "never produced (malformed schedule table):\n"
                + "\n".join(lines))
    n_slots = max(dev_level) + 1
    grid = [[None] * n_slots for _ in range(p)]
    for d in range(p):
        for slot, ins in slotted[d]:
            grid[d][slot] = ins
    return grid


def encode(grid, pl: Placement) -> np.ndarray:
    """-> int32 codes of shape (n_slots, p, 6):
    [f_code, f_mb, b_code, b_mb, w_code, w_mb], indices into the
    placement's F_BRANCHES / B_BRANCHES / W_BRANCHES vocabularies."""
    p = pl.p
    fb, bb, wb = F_BRANCHES[pl.kind], B_BRANCHES[pl.kind], W_BRANCHES[pl.kind]
    n_slots = len(grid[0])
    codes = np.zeros((n_slots, p, 6), np.int32)
    for d in range(p):
        for t, ins in enumerate(grid[d]):
            if ins is None:
                continue
            if ins.f is not None:
                codes[t, d, 0] = fb.index(f_role(pl, ins.f[0], d))
                codes[t, d, 1] = ins.f[1]
            if ins.b is not None:
                codes[t, d, 2] = bb.index(b_role(pl, ins.b[0], d))
                codes[t, d, 3] = ins.b[1]
            if ins.w is not None:
                codes[t, d, 4] = wb.index(w_role(pl, ins.w[0], d))
                codes[t, d, 5] = ins.w[1]
    # p >= 2 is enforced at Placement construction / schedule.build: a
    # single-stage "pipeline" would build empty ppermute perms and silently
    # zero the boundary streams.
    return codes


# ---------------------------------------------------------------------------
# Fused-lowering plan: maximal constant-role segments of the slot grid.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Segment:
    """A maximal run of slots [start, stop) whose per-device branch-role
    rows (f_code, b_code, w_code) repeat with a fixed ``period``.  Within a
    segment the ``lax.switch`` selection is static per (device, phase):
    only microbatch indices vary iteration-to-iteration, so the executor
    can lower the whole run as one scan whose body unrolls the period's
    phases — dispatching once per phase over that phase's distinct role
    rows (zero dispatches when all devices share one row) and exchanging
    only that phase's statically-live boundary streams.  ``period == 1`` is
    the constant-role case; period > 1 captures steady-state braids (1f1b
    and the zero-bubble family alternate roles every slot, so without
    periodic detection every steady slot would inline as its own
    straight-line segment and the traced program grows with ``m``)."""
    start: int
    stop: int
    phases: tuple        # per-phase tuple of per-device (f, b, w) rows
    live: tuple          # per-phase ((up streams), (dn streams)) pairs

    @property
    def length(self) -> int:
        return self.stop - self.start

    @property
    def period(self) -> int:
        return len(self.phases)

    @property
    def n_iters(self) -> int:
        return self.length // self.period

    # -- period-1 convenience views (constant-role segments) --------------
    @property
    def rows(self) -> tuple:
        assert self.period == 1
        return self.phases[0]

    @property
    def live_up(self) -> tuple:
        assert self.period == 1
        return self.live[0][0]

    @property
    def live_dn(self) -> tuple:
        assert self.period == 1
        return self.live[0][1]

    @property
    def n_rows(self) -> int:
        assert self.period == 1
        return len(set(self.phases[0]))


def _live_streams(rows, kind: str):
    """Streams some device emits into, split by exchange direction."""
    fb, bb = F_BRANCHES[kind], B_BRANCHES[kind]
    emitted = set()
    for fc, bc, wc in rows:
        emitted.add(EMITS.get(fb[fc]))
        emitted.add(EMITS.get(bb[bc]))
    w = WIRING[kind]
    return (tuple(s for s in w["up"] if s in emitted),
            tuple(s for s in w["dn"] if s in emitted))


def segment_grid(codes: np.ndarray, kind: str, *,
                 max_period: int = 4) -> list:
    """Partition encoded slot codes (n_slots, p, 6) into maximal
    :class:`Segment` runs of ``period``-repeating per-device role rows.

    Greedy longest-match: at each position the constant run (period 1) is
    extended first; a larger period up to ``max_period`` wins only when its
    (period-truncated) run covers strictly more slots and repeats at least
    twice — a single repetition is just straight-line code, not a loop."""
    n_slots, p = codes.shape[0], codes.shape[1]
    rows = [tuple(tuple(int(c) for c in codes[t, d, 0::2])
                  for d in range(p)) for t in range(n_slots)]

    def mk(start, stop, period):
        phases = tuple(rows[start + j] for j in range(period))
        return Segment(start, stop, phases,
                       tuple(_live_streams(ph, kind) for ph in phases))

    segs, t = [], 0
    while t < n_slots:
        best_k = 1
        best_l = 1
        while t + best_l < n_slots and rows[t + best_l] == rows[t]:
            best_l += 1
        for k in range(2, max_period + 1):
            if t + 2 * k > n_slots:
                break
            run = 0
            while (t + run < n_slots
                   and rows[t + run] == rows[t + run % k]):
                run += 1
            run -= run % k
            if run >= 2 * k and run > best_l:
                best_k, best_l = k, run
        segs.append(mk(t, t + best_l, best_k))
        t += best_l
    return segs


def recv_rows(codes: np.ndarray, seg: Segment, kind: str, m: int
              ) -> tuple:
    """Static receive rows for the fused exchange, one array per phase of
    shape (seg.n_iters, p, n_live): the mb row each device writes a
    received live-stream payload into, ordered [live_up..., live_dn...].
    Row ``m`` (the scratch row) when the device has no emitting upstream —
    statically replacing the generic path's transmitted validity flags."""
    w = WIRING[kind]
    p = codes.shape[1]
    fb, bb = F_BRANCHES[kind], B_BRANCHES[kind]
    names = (fb, None, bb)           # indexed by _ROLE_COL
    out = []
    for ph, (up, dn) in zip(seg.phases, seg.live):
        j = len(out)
        streams = list(up) + list(dn)
        mbc = codes[seg.start + j:seg.stop:seg.period]   # (n_iters, p, 6)
        arr = np.full((seg.n_iters, p, len(streams)), m, np.int32)
        for i, s in enumerate(streams):
            shift = 1 if s in up else -1
            rcol, mcol = _ROLE_COL[s], _MB_COL[s]
            vocab = names[rcol]
            for d in range(p):
                src = d - shift
                if w["wrap"]:
                    src %= p
                elif not (0 <= src < p):
                    continue
                if EMITS.get(vocab[ph[src][rcol // 2]]) != s:
                    continue
                arr[:, d, i] = mbc[:, src, mcol]
        out.append(arr)
    return tuple(out)


def offload_plan(ops_tables, grid, pl: Placement, m: int) -> np.ndarray:
    """Static per-slot fetch/read plan for the executor's §4.4 activation
    offload, derived from an :func:`repro.core.simulator.annotate_offload`'d
    table and the pure table's slot ``grid``.

    -> int32 array of shape (n_slots, p, 3):

      ``[:, :, 0]``  microbatch whose offloaded α-slice to FETCH at the
                     *end* of this slot's body (``m`` = no fetch),
      ``[:, :, 1]``  staging row (0/1) that fetch writes,
      ``[:, :, 2]``  staging row this slot's chunk-0 B (if any) reads.

    Double-buffering invariant: the annotated stream puts FETCH(vs, mb)
    immediately before the instruction carrying B(vs, mb), so the fetch is
    planned one slot ahead of its B — at the end of slot ``t_B - 1``'s body,
    i.e. before the B of the *previous* offloaded microbatch when Bs run
    back-to-back.  Fetch event *i* writes staging row ``i % 2``; per-device
    B slots strictly increase, so event *i+2*'s fetch (at slot
    ``t_B[i+2] - 1 >= t_B[i] + 1``) always lands after event *i*'s read —
    a staging row is never clobbered before it is consumed."""
    p = pl.p
    n_slots = len(grid[0])
    plan = np.zeros((n_slots, p, 3), np.int32)
    plan[:, :, 0] = m
    for d in range(p):
        islots = [t for t, ins in enumerate(grid[d]) if ins is not None]
        k = 0
        f_slot: dict = {}
        events: list = []            # (fetch_slot, b_slot, vs, mb)
        pending: list = []
        for op in ops_tables[d]:
            if isinstance(op, OffloadOp):
                if op.op == "FETCH":
                    pending.append((op.vs, op.mb))
                else:
                    # OFFLOAD follows the instr carrying the F: the α-slice
                    # is written to host as part of that slot's dispatch.
                    f_slot[(op.vs, op.mb)] = islots[k - 1]
                continue
            t = islots[k]
            k += 1
            for vs, mb in pending:
                events.append((t - 1, t, vs, mb))
            pending = []
        if pending:
            raise RuntimeError(
                f"device {d}: trailing FETCH with no consuming instruction")
        for i, (ft, bt, vs, mb) in enumerate(events):
            if ft < f_slot[(vs, mb)]:
                raise RuntimeError(
                    f"device {d}: FETCH({vs},{mb}) planned at slot {ft} "
                    f"before its OFFLOAD at slot {f_slot[(vs, mb)]}")
            if i >= 2 and ft <= events[i - 2][1]:
                raise RuntimeError(
                    f"device {d}: staging row {i % 2} would be overwritten "
                    f"at slot {ft} before its slot-{events[i - 2][1]} read")
            plan[ft, d, 0] = mb
            plan[ft, d, 1] = i % 2
            plan[bt, d, 2] = i % 2
    return plan


def plan_stats(codes: np.ndarray, kind: str, *, fused: bool) -> dict:
    """Static per-step cost counters of a lowering plan: how many
    ``lax.switch`` dispatches and ppermute'd tensors one pipeline step
    executes.  The generic lowering pays 3 switches per slot and, per slot,
    every wired stream as a (payload, mb-flag) pair; the fused lowering
    pays at most one switch per slot (none in single-row segments) and one
    payload tensor per statically-live stream."""
    n_slots, p = codes.shape[0], codes.shape[1]
    n_streams = sum(len(WIRING[kind][k]) for k in ("up", "dn"))
    if not fused:
        return {"n_slots": n_slots, "n_segments": n_slots,
                "n_dispatches": 3 * n_slots,
                "n_ppermutes": 2 * n_streams * n_slots}
    segs = segment_grid(codes, kind)
    return {
        "n_slots": n_slots,
        "n_segments": len(segs),
        "n_dispatches": sum(
            s.n_iters * sum(1 for ph in s.phases if len(set(ph)) > 1)
            for s in segs),
        "n_ppermutes": sum(
            s.n_iters * sum(len(up) + len(dn) for up, dn in s.live)
            for s in segs),
    }
