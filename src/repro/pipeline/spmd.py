"""SPMD pipeline executor: shard_map over a real ``stage`` mesh axis.

One scanned program executes every device's instruction stream in lockstep
slots.  Per slot each device

  1. selects its instruction codes (``lax.switch`` over F/B/W sub-steps;
     a braided F&B block is simply a slot whose F- and B-parts are both
     active — inside one jitted slot their computations are data-independent,
     which is precisely the legal-overlap window the paper engineers),
  2. exchanges boundary tensors with its neighbours via two ``ppermute``s
     whose wiring depends on the placement (``pipeline.slots.WIRING``):

     flat      shift +1 carries activations, shift -1 gradients;
     parallel  both chunks' activations ride +1 and gradients -1 on a
               *wrapped* stage ring (the chunk-0 -> chunk-1 hand-off goes
               from device p-1 back to device 0);
     vshape    shift +1 carries chunk-0 activations and chunk-1 gradients
               (the "V" down-sweep), shift -1 carries chunk-1 activations
               and chunk-0 gradients; turn and loss are device-local.

All six schedule kinds in ``repro.core.schedule.SCHEDULES`` lower through
this one runtime: table -> verified instruction IR -> slot grid -> scanned
shard_map program.  Stages may hold *different* layer counts: the shared
``core.schedule.partition`` maps layers to contiguous per-virtual-stage
ranges (explicit or cost-balanced), stacks are zero-padded per chunk to the
chunk's deepest stage, and devices whose (chunk0, chunk1) ranges differ
dispatch through distinct switch arms keyed by their partition *signature*
— each arm loops over its own static layer count, so pad rows are never
computed on and their grads/updates stay exactly zero.  TP optionally
composes via a ``model`` mesh axis.

Two entry points share the program body: ``build_pipeline_step`` returns
gradients to the host (differential tests), while
``build_pipeline_train_step`` additionally fuses global-norm clipping and
the AdamW update *under* the same ``shard_map``, so stacked params and
optimizer moments stay mesh-resident across steps (the ``SpmdRunner``
path — no per-step host re-stacking).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.schedule import partition
from repro.core.simulator import (Placement, annotate_offload, flat, parallel,
                                  vshape)
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim.adamw import OptConfig, adamw_leaf, adamw_scalars
from repro.pipeline import slots as SL
from repro.tp.context import TPContext

_PLACEMENTS = {"flat": flat, "parallel": parallel, "vshape": vshape}


def stages_per_chunk(cfg: ModelConfig, p: int, kind: str = "vshape") -> int:
    """Layers per virtual stage of a *uniform* stack (legacy helper; the
    executor itself is partition-generic — see ``core.schedule.partition``)."""
    n_vs = _PLACEMENTS[kind](p).n_vs
    n = cfg.n_layers
    assert n % n_vs == 0, \
        f"uniform stacks need n_layers % n_vs == 0 (n={n}, n_vs={n_vs})"
    return n // n_vs


def _part_bounds(part, p: int, kind: str) -> tuple[tuple[int, int], ...]:
    """Normalize a partition argument: an int is the legacy uniform
    layers-per-virtual-stage count; anything else is a per-virtual-stage
    (start, stop) range sequence (as produced by ``core.schedule.partition``)."""
    if isinstance(part, (int, np.integer)):
        n_vs = _PLACEMENTS[kind](p).n_vs
        return tuple((i * part, (i + 1) * part) for i in range(n_vs))
    bounds = tuple((int(a), int(b)) for a, b in part)
    for i, (a, b) in enumerate(bounds):
        if b <= a:
            raise ValueError(
                f"SPMD executor requires a non-empty layer range per "
                f"virtual stage; stage {i} got [{a},{b})")
    return bounds


def default_part(cfg: ModelConfig, p: int, kind: str = "vshape"
                 ) -> tuple[tuple[int, int], ...]:
    """Cost-balanced per-virtual-stage layer ranges for (cfg, placement)."""
    return partition(cfg, _PLACEMENTS[kind](p).n_vs)


def stack_stages(blocks, p: int, part, kind: str = "vshape"):
    """Per-layer pytree list -> (chunk0, chunk1) stacked with leading
    (p, Lmax_chunk) dims, where ``part`` gives each virtual stage's
    contiguous (start, stop) layer range (a bare int means the legacy
    uniform layers-per-stage stack).  Stages holding fewer layers than the
    chunk's deepest stage are zero-padded at the tail; pad rows are never
    computed on and their grads/optimizer updates stay exactly zero.

    Stacking is in *device* order per chunk:

      flat      chunk0 vs s = device s; chunk1 empty ({}).
      parallel  chunk0 vs s = device s; chunk1 vs p+s = device s.
      vshape    chunk0 vs s = device s; chunk1 vs 2p-1-s = device s
                (i.e. chunk1 stages stacked in reversed vs order).

    Works on any canonical per-layer list (params, AdamW moments, grads).
    """
    bounds = _part_bounds(part, p, kind)
    pl = _PLACEMENTS[kind](p)

    def stack(layers):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)

    def chunk_of(c):
        rngs = [bounds[pl.vs_of(s, c)] for s in range(p)]
        lmax = max(b - a for a, b in rngs)
        rows = []
        for a, b in rngs:
            layers = list(blocks[a:b])
            pad = jax.tree.map(jnp.zeros_like, layers[-1])
            rows.append(stack(layers + [pad] * (lmax - (b - a))))
        return stack(rows)

    c0 = chunk_of(0)
    if kind == "flat":
        return c0, {}
    return c0, chunk_of(1)


def unstack_stages(c0, c1, n_layers: int, p: int, part,
                   kind: str = "vshape"):
    """Inverse of ``stack_stages``: back to the per-layer pytree list
    (padding rows dropped)."""
    bounds = _part_bounds(part, p, kind)
    pl = _PLACEMENTS[kind](p)
    blocks = [None] * n_layers
    chunks = [(0, c0)] + ([] if kind == "flat" else [(1, c1)])
    for s in range(p):
        for c, arr in chunks:
            a, b = bounds[pl.vs_of(s, c)]
            for i in range(b - a):
                blocks[a + i] = jax.tree.map(lambda x: x[s, i], arr)
    return blocks


def stack_stage_params(params, cfg: ModelConfig, p: int,
                       kind: str = "vshape", part=None):
    """Canonical params -> (chunk0, chunk1, part); see ``stack_stages``.
    ``part`` defaults to the shared cost-balanced partition; the returned
    value is what ``unstack_stage_grads`` expects back."""
    bounds = (default_part(cfg, p, kind) if part is None
              else _part_bounds(part, p, kind))
    c0, c1 = stack_stages(params["blocks"], p, bounds, kind)
    return c0, c1, bounds


def unstack_stage_grads(g0, g1, cfg: ModelConfig, p: int, part,
                        kind: str = "vshape"):
    """Inverse of ``stack_stage_params`` for the gradient pytrees."""
    return unstack_stages(g0, g1, cfg.n_layers, p, part, kind)


def _zeros_like_tree(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), tree)


def _memory_kind(kind: str):
    from jax._src.sharding_impls import TransferToMemoryKind
    return TransferToMemoryKind(kind)


@functools.lru_cache(maxsize=1)
def host_offload_supported() -> bool:
    """Whether the backend honours ``pinned_host`` memory-space annotations
    inside jit (checked by a device_put round-trip probe).  When it does
    not, the offload lowering falls back to plain unannotated buffers — on
    CPU the default memory space already *is* host memory, so the fallback
    pool is host-side by construction."""
    try:
        y = jax.jit(lambda v: jax.device_put(
            jax.device_put(v, _memory_kind("pinned_host")),
            _memory_kind("device")))(jnp.arange(8, dtype=jnp.float32))
        return bool(np.asarray(jax.block_until_ready(y))[3] == 3.0)
    except Exception:
        return False


# ---------------------------------------------------------------------------
# Megatron-style TP sharding rules for the unit-mode (shard_map) params.
# Column-parallel: qkv / up projections split their output dim; row-parallel:
# down/out projections split their input dim; norm gains, routers and small
# core params are replicated; the LM head is vocab-parallel.
# ---------------------------------------------------------------------------

def _tp_axis_of(name: str, base_ndim: int):
    """TP shard axis (negative, counted from the right) for a named param,
    or None if replicated.  Column-parallel projections split their output
    dim, row-parallel split their input dim, heads axes shard for the
    head-blocked mLSTM mixers; routers / norms / small cores replicate.
    sLSTM in-projections interleave four gate blocks and stay replicated
    (DESIGN.md §Arch-applicability)."""
    col2 = {"wg", "wu", "w1", "w_in_x", "w_in_z", "w_upx", "w_upz", "w_lm"}
    row2 = {"wo", "wd", "w2", "w_out", "w_down"}
    if name in ("wq", "wk", "wv"):
        return -3 if base_ndim >= 3 else -1              # mlstm heads / attn
    if name in ("wi", "wf"):
        return -2                                        # mlstm gate heads
    if name in col2:
        return -1
    if name in row2:
        return -2
    return None


def _ep_axis_of(name: str, base_ndim: int):
    """Expert-parallel shard axis for a named param, or None.  MoE expert
    weights (E, d, f) / (E, f, d) shard their leading expert dim; the dense
    MLP reuses the same names at base rank 2 and stays unsharded."""
    if name in ("wg", "wu", "wd") and base_ndim >= 3:
        return -3
    return None


def tp_specs(tree, model_axis: Optional[str], stage_axis: Optional[str],
             lead: int = 0, expert_axis: Optional[str] = None):
    """PartitionSpec tree for a params pytree.  ``lead`` extra leading dims
    (stage stack + per-vs layer stack) precede the parameter's own dims; if
    ``stage_axis`` is given it names the first of them.  ``expert_axis``
    additionally shards MoE expert weights over their E dim."""
    def one(path, leaf):
        name = None
        for k in reversed(path):
            if hasattr(k, "key"):
                name = k.key
                break
        spec = [None] * leaf.ndim
        if stage_axis is not None:
            spec[0] = stage_axis
        ax = _tp_axis_of(name, leaf.ndim - lead) if model_axis else None
        if ax is not None:
            spec[leaf.ndim + ax] = model_axis
        eax = (_ep_axis_of(name, leaf.ndim - lead) if expert_axis else None)
        if eax is not None:
            spec[leaf.ndim + eax] = expert_axis
        return P(*spec)
    return jax.tree_util.tree_map_with_path(one, tree)


def _read(buf, mb):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, mb, 0, keepdims=False), buf)


def _write(buf, mb, val):
    return jax.tree.map(
        lambda a, v: jax.lax.dynamic_update_index_in_dim(
            a, v.astype(a.dtype), mb, 0), buf, val)


def _local_sds(tree, tp_size: int, lead: int, strip: int, ep_size: int = 1):
    """ShapeDtypeStructs of the per-device shards: drop ``strip`` leading
    (stage) dims and divide TP-ruled (and EP-ruled) axes by the axis size."""
    def one(path, leaf):
        name = None
        for k in reversed(path):
            if hasattr(k, "key"):
                name = k.key
                break
        shape = list(leaf.shape[strip:])
        ax = _tp_axis_of(name, leaf.ndim - lead)
        if ax is not None and tp_size > 1:
            shape[ax] = shape[ax] // tp_size
        eax = _ep_axis_of(name, leaf.ndim - lead)
        if eax is not None and ep_size > 1:
            shape[eax] = shape[eax] // ep_size
        return jax.ShapeDtypeStruct(tuple(shape), leaf.dtype)
    return jax.tree_util.tree_map_with_path(one, tree)


def _layers(cparams, count):
    """Unstack a chunk's stacked per-layer params into a per-layer list."""
    return [jax.tree.map(lambda a: a[i], cparams) for i in range(count)]


def _program_shapes(cfg: ModelConfig, pl: Placement, mb_shape, param_trees,
                    *, tp_size: int = 1, ep_size: int = 1, part=None) -> dict:
    """Static per-device partition signatures and ctx/tape/head buffer
    ShapeDtypeStructs — shared by the slot-program lowering and the offload
    byte accounting (:func:`activation_buffer_stats`).

    Buffer shapes are traced with an identity TPContext over the *local*
    shard shapes — collectives preserve shapes, so the unit-mode buffers
    match (eval_shape cannot bind mesh axis names)."""
    p = pl.p
    two_chunks = pl.kind != "flat"
    bounds = (default_part(cfg, p, pl.kind) if part is None
              else _part_bounds(part, p, pl.kind))
    rng = {0: [bounds[pl.vs_of(d, 0)] for d in range(p)]}
    if two_chunks:
        rng[1] = [bounds[pl.vs_of(d, 1)] for d in range(p)]
    chunk_ids = sorted(rng)
    sig_of_dev = [tuple(rng[c][d] for c in chunk_ids) for d in range(p)]
    sigs = list(dict.fromkeys(sig_of_dev))
    sig_id = np.array([sigs.index(s) for s in sig_of_dev], np.int32)
    lmax = {c: max(b - a for a, b in rng[c]) for c in chunk_ids}

    bmb, seq = mb_shape
    rope = M._rope_for(cfg, seq)
    x_sds = jax.ShapeDtypeStruct((bmb, seq, cfg.d_model), jnp.float32)
    lab_sds = jax.ShapeDtypeStruct((bmb, seq), jnp.int32)

    def specs_of(r):
        return cfg.layers[r[0]:r[1]]

    tp0 = TPContext(expert_size=ep_size)
    cp_sds = {0: _local_sds(param_trees[0], tp_size, lead=2, strip=1,
                            ep_size=ep_size)}
    if two_chunks:
        cp_sds[1] = _local_sds(param_trees[1], tp_size, lead=2, strip=1,
                               ep_size=ep_size)

    def _raw_sds(r, c):
        count = r[1] - r[0]
        _, cx = jax.eval_shape(
            lambda cp, x: M.chunk_fwd(_layers(cp, count), tp0, x, rope,
                                      specs_of(r), cfg), cp_sds[c], x_sds)
        _, tps, _ = jax.eval_shape(
            lambda cp, cxs, g: M.chunk_bwd_act(_layers(cp, count), tp0, cxs,
                                               g, specs_of(r), cfg),
            cp_sds[c], cx, x_sds)
        return cx, tps

    def _leaf_sig(tree):
        return (jax.tree.structure(tree),
                tuple((l.shape, str(l.dtype)) for l in jax.tree.leaves(tree)))

    # Per-chunk ctx/tape buffers sized to the chunk's deepest stage.  The
    # structure at stack position l must agree across every stage of the
    # chunk that owns a layer there — one carry serves all devices.
    ctx_sds, tape_sds = {}, {}
    for c in chunk_ids:
        per_rng = {r: _raw_sds(r, c) for r in dict.fromkeys(rng[c])}
        buf_ctx, buf_tape = [], []
        for l in range(lmax[c]):
            owners = [r for r in per_rng if r[1] - r[0] > l]
            ref = per_rng[owners[0]]
            for r in owners[1:]:
                got = per_rng[r]
                if (_leaf_sig(ref[0][l]) != _leaf_sig(got[0][l])
                        or _leaf_sig(ref[1][l]) != _leaf_sig(got[1][l])):
                    raise ValueError(
                        f"heterogeneous layer kinds at stack position {l} "
                        f"of chunk {c} (ranges {owners[0]} vs {r}): stages "
                        "sharing a chunk stack must align structurally — "
                        "pass explicit partition ranges that align layer "
                        "kinds, or run through pipeline.reference")
            buf_ctx.append(ref[0][l])
            buf_tape.append(ref[1][l])
        ctx_sds[c] = buf_ctx
        tape_sds[c] = buf_tape

    head_sds = _local_sds(param_trees[3], tp_size, lead=0, strip=0)
    _, hctx_sds = jax.eval_shape(
        lambda hp, x, lab: M.head_fwd(hp, tp0, x, lab, cfg),
        head_sds, x_sds, lab_sds)
    _, htape_sds, _ = jax.eval_shape(
        lambda hp, c: M.head_bwd_act(hp, tp0, c, jnp.float32(1.0), cfg),
        head_sds, hctx_sds)
    return dict(two_chunks=two_chunks, bounds=bounds, rng=rng,
                chunk_ids=chunk_ids, sigs=sigs, sig_id=sig_id, lmax=lmax,
                rope=rope, x_sds=x_sds, lab_sds=lab_sds,
                ctx_sds=ctx_sds, tape_sds=tape_sds,
                hctx_sds=hctx_sds, htape_sds=htape_sds)


def _off_k(shape, alpha: float) -> int:
    """Offloaded element count of one flattened ctx leaf: ``int(α·size)``."""
    return int(alpha * int(np.prod(shape)))


def activation_buffer_stats(cfg: ModelConfig, pl: Placement, m: int,
                            mb_shape, param_trees, *, tp_size: int = 1,
                            ep_size: int = 1, part=None,
                            offload_alpha: float = 0.0) -> dict:
    """Static byte accounting of the executor's per-device activation
    carries, split device-resident vs host-offloaded.

    The headline ``device_act_bytes`` counts the F→B context buffers
    (chunk-0 resident slices + the two offload staging rows + chunk-1 +
    loss-head contexts) — exactly the state §4.4's α shrinks.  The B→W
    tapes and the (m+1)-row boundary stream buffers are reported separately
    for transparency (offload does not touch them)."""
    alpha = float(offload_alpha)
    sh = _program_shapes(cfg, pl, mb_shape, param_trees, tp_size=tp_size,
                         ep_size=ep_size, part=part)

    def nbytes(tree):
        return sum(int(np.prod(s.shape)) * s.dtype.itemsize
                   for s in jax.tree.leaves(tree))

    ctx0_row = nbytes(sh["ctx_sds"][0])
    off_row = sum(_off_k(s.shape, alpha) * s.dtype.itemsize
                  for s in jax.tree.leaves(sh["ctx_sds"][0]))
    ctx1_row = nbytes(sh["ctx_sds"][1]) if sh["two_chunks"] else 0
    hctx_row = nbytes(sh["hctx_sds"])
    tape_rows = (nbytes(sh["tape_sds"][0]) + nbytes(sh["htape_sds"])
                 + (nbytes(sh["tape_sds"][1]) if sh["two_chunks"] else 0))
    bmb, seq = mb_shape
    n_streams = 2 if pl.kind == "flat" else 4
    boundary = n_streams * (m + 1) * bmb * seq * cfg.d_model * 4
    device_act = (m * (ctx0_row - off_row) + 2 * off_row
                  + m * (ctx1_row + hctx_row))
    return {
        "offload_alpha": alpha,
        "m": m,
        "ctx0_row_bytes": ctx0_row,
        "ctx0_offloaded_row_bytes": off_row,
        "device_act_bytes": device_act,
        "host_act_bytes": m * off_row,
        "tape_bytes": m * tape_rows,
        "boundary_bytes": boundary,
        "device_total_bytes": device_act + m * tape_rows + boundary,
    }


def _pipeline_program(cfg: ModelConfig, tables, pl: Placement, mesh: Mesh,
                      m: int, mb_shape, param_trees, *,
                      stage_axis: str = "stage",
                      model_axis: Optional[str] = None,
                      expert_axis: Optional[str] = None,
                      fuse: bool = True, ablate: Optional[str] = None,
                      braid_tp: bool = False, part=None,
                      offload_alpha: float = 0.0):
    """Build the per-device slot program ``run(c0, c1, embed_p, head_p,
    tokens, labels) -> (loss, g0, g1, g_embed, g_head)`` to be wrapped in
    ``shard_map`` — shared by the grads-only step and the fused train step.

    ``fuse`` selects the lowering of the static slot grid:

      False — generic: one scan over all slots, three ``lax.switch``
              dispatches per slot (F/B/W), every wired boundary stream
              exchanged every slot as a (payload, mb-flag) ppermute pair.
      True  — fused (default): the grid is partitioned into maximal
              constant-role *segments* (``slots.segment_grid``).  Each
              segment lowers as its own scan whose body composes the three
              branch bodies at trace time (role codes are Python ints per
              segment), leaving at most ONE ``lax.switch`` per slot — over
              the segment's distinct per-device role rows, and none at all
              when the row is uniform — and exchanges only the segment's
              statically-live streams as bare payloads (receive rows are
              read from the static grid, so the flag channel disappears).

    Both lowerings share the same branch bodies, so they are numerically
    identical up to float reassociation (pinned by the differential tests).

    ``ablate`` builds benchmark-only variants for the ``--breakdown`` cost
    split (numerics are meaningless): ``"exchange"`` elides every ppermute;
    ``"compute"`` replaces branch bodies with buffer-touching stubs that
    keep the dispatch + exchange structure (and a loss data-dependence so
    XLA cannot dead-code it); ``"both"`` applies both; ``"tp"`` executes
    the full model math with an identity TPContext (no model-axis
    collectives; shard shapes keep the real TP size), isolating the
    TP-collective share of the wall clock.

    ``braid_tp`` lowers composite F&B slots through the braided chunk
    executor (``model.chunk_fwd_bwd_braided``): unit-interleaved partner
    chunks with ring-decomposed output collectives, instead of the
    sequential chunk_f-then-chunk_b composition.

    ``part`` gives the per-virtual-stage contiguous layer ranges (default:
    cost-balanced ``core.schedule.partition``).  Devices are grouped into
    partition *signatures* — the (chunk0 range, chunk1 range) pair — and
    every dispatch arm is specialised per signature: the arm's chunk loops
    run its own static layer counts, so one traced program serves stages of
    different depths (uniform partitions collapse to a single signature and
    trace exactly the old program).

    ``offload_alpha`` > 0 enables the §4.4 activation-offload lowering:
    each chunk-0 ctx row leaf is flattened and split at ``k = int(α·size)``
    — the first k elements move to a host-memory ``(m, k)`` buffer when the
    slot that runs F completes, and are staged back on device one slot
    ahead of their B (``slots.offload_plan``, double-buffered over two
    staging rows), while the remaining ``size - k`` stay in the scanned
    device carry.  The split/join is pure reshape + concatenation, so
    ``offload_alpha = 0.0`` traces byte-for-byte today's program and any
    α > 0 is bitwise-identical math on re-joined values.
    """
    assert ablate in (None, "exchange", "compute", "both", "tp")
    do_exchange = ablate not in ("exchange", "both")
    braid = braid_tp and ablate not in ("compute", "both")
    p = pl.p
    two_chunks = pl.kind != "flat"
    grid = SL.to_slots(tables, pl)
    codes_np = SL.encode(grid, pl)                      # (L, p, 6) static
    off_alpha = float(offload_alpha)
    off_on = off_alpha > 0.0
    if off_on and ablate is not None:
        raise ValueError("offload_alpha composes with the real program only "
                         "(ablate variants are benchmark-only stubs)")
    off_plan_np = (SL.offload_plan(annotate_offload(tables, pl), grid, pl, m)
                   if off_on else None)
    wiring = SL.WIRING[pl.kind]
    act_streams = tuple(s for s in ("x0", "x1")
                        if s in wiring["up"] + wiring["dn"])
    grad_streams = tuple(s for s in ("g0", "g1")
                         if s in wiring["up"] + wiring["dn"])
    # safe_ring: braided ring hops run inside lax.switch arms that only
    # some stage rows take; ppermute would deadlock there (XLA:CPU
    # rendezvouses collective-permute globally), so hops lower as per-group
    # one-hot psums instead.
    ep_size = mesh.shape[expert_axis] if expert_axis else 1
    tp = TPContext(axis=model_axis,
                   size=(mesh.shape[model_axis] if model_axis else 1),
                   safe_ring=True,
                   expert_axis=expert_axis, expert_size=ep_size)
    # ablate="tp": execute with an identity context (no model-axis
    # collectives) while `tp` keeps the real size for shard shapes.
    tp_exec = TPContext() if ablate == "tp" else tp

    sh = _program_shapes(cfg, pl, mb_shape, param_trees, tp_size=tp.size,
                         ep_size=ep_size, part=part)
    chunk_ids, sigs = sh["chunk_ids"], sh["sigs"]
    sig_id, rope = sh["sig_id"], sh["rope"]
    ctx_sds, tape_sds = sh["ctx_sds"], sh["tape_sds"]
    hctx_sds, htape_sds = sh["hctx_sds"], sh["htape_sds"]

    bmb, seq = mb_shape
    d_model = cfg.d_model
    scale = 1.0 / m

    def specs_of(r):
        return cfg.layers[r[0]:r[1]]

    # --- §4.4 offload: resident/offloaded split of chunk-0 ctx rows ------
    if off_on:
        to_host, to_dev = (
            ((lambda t: jax.device_put(t, _memory_kind("pinned_host"))),
             (lambda t: jax.device_put(t, _memory_kind("device"))))
            if host_offload_supported()
            else ((lambda t: t), (lambda t: t)))
        ctx0_res_sds = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                (int(np.prod(s.shape)) - _off_k(s.shape, off_alpha),),
                s.dtype), ctx_sds[0])
        ctx0_off_sds = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((_off_k(s.shape, off_alpha),),
                                           s.dtype), ctx_sds[0])

        def _off_part(ctxs):
            return jax.tree.map(
                lambda x: x.reshape(-1)[:_off_k(x.shape, off_alpha)], ctxs)

        def _res_part(ctxs):
            return jax.tree.map(
                lambda x: x.reshape(-1)[_off_k(x.shape, off_alpha):], ctxs)

        def _join_off(res, off):
            return jax.tree.map(
                lambda s, r, o: jnp.concatenate([o, r]).reshape(s.shape),
                ctx_sds[0], res, off)

    def zeros_of(sds_tree, lead=None):
        return jax.tree.map(
            lambda s: jnp.zeros(((lead,) + s.shape) if lead else s.shape,
                                s.dtype), sds_tree)

    def _pad_to(buf_sds, vals):
        """Pad a per-layer ctx/tape list to the buffer depth with zeros."""
        return list(vals) + [zeros_of(s) for s in buf_sds[len(vals):]]

    def make_chunk_ops(sig):
        """Chunk executors specialised to one partition signature: each
        loops its chunk's own static layer count and pads ctx/tape lists to
        the shared buffer depth."""
        rr = dict(zip(chunk_ids, sig))

        def chunk_f(which, cparams, x, tpc=tp_exec):
            r = rr[which]
            y, ctxs = M.chunk_fwd(_layers(cparams, r[1] - r[0]), tpc, x,
                                  rope, specs_of(r), cfg)
            return y, _pad_to(ctx_sds[which], ctxs)

        def chunk_b(which, cparams, ctxs, gy, tpc=tp_exec):
            r = rr[which]
            gx, tapes, joints = M.chunk_bwd_act(
                _layers(cparams, r[1] - r[0]), tpc, ctxs[:r[1] - r[0]], gy,
                specs_of(r), cfg)
            return gx, _pad_to(tape_sds[which], tapes), joints

        def chunk_fb(fck, bck, f_cparams, x, b_cparams, ctxs, gy):
            rf, rb = rr[fck], rr[bck]
            y, fcx, gx, tapes, joints = M.chunk_fwd_bwd_braided(
                _layers(f_cparams, rf[1] - rf[0]), x,
                _layers(b_cparams, rb[1] - rb[0]), ctxs[:rb[1] - rb[0]], gy,
                tp_exec, rope, specs_of(rf), cfg, b_specs=specs_of(rb))
            return (y, _pad_to(ctx_sds[fck], fcx), gx,
                    _pad_to(tape_sds[bck], tapes), joints)

        def chunk_w(which, tapes):
            r = rr[which]
            return M.chunk_bwd_weight(tapes[:r[1] - r[0]], specs_of(r))

        return chunk_f, chunk_b, chunk_fb, chunk_w

    sig_ops = [make_chunk_ops(s) for s in sigs]

    def run(c0, c1, embed_p, head_p, tokens, labels):
        """Per-device body (inside shard_map).  c0/c1 carry a leading
        stage dim of 1 (c1 is the empty pytree for flat placements)."""
        c0 = jax.tree.map(lambda a: a[0], c0)
        c1 = jax.tree.map(lambda a: a[0], c1)
        zrow = lambda: jnp.zeros((m + 1, bmb, seq, d_model), jnp.float32)
        carry = {
            "x0": zrow(), "g0": zrow(),
            "ctx0": (zeros_of(ctx0_res_sds, m) if off_on
                     else zeros_of(ctx_sds[0], m)),
            "tape0": zeros_of(tape_sds[0], m),
            "hctx": zeros_of(hctx_sds, m), "htape": zeros_of(htape_sds, m),
            "loss": jnp.zeros((m,), jnp.float32),
            "a0": _zeros_like_tree(c0),
            "ae": _zeros_like_tree(embed_p),
            "ah": _zeros_like_tree(head_p),
        }
        if two_chunks:
            carry.update({
                "x1": zrow(), "g1": zrow(),
                "ctx1": zeros_of(ctx_sds[1], m),
                "tape1": zeros_of(tape_sds[1], m),
                "a1": _zeros_like_tree(c1),
            })
        if off_on:
            # (m, k) host pool + two on-device staging rows + the staging
            # row selector the current slot's chunk-0 B reads through.
            carry["ctx0_off"] = to_host(zeros_of(ctx0_off_sds, m))
            carry["ctx0_stage"] = zeros_of(ctx0_off_sds, 2)
            carry["osel"] = jnp.int32(0)

        def _ctx_write(carry, mb, which, ctxs):
            if which == 0 and off_on:
                return dict(
                    carry,
                    ctx0=_write(carry["ctx0"], mb, _res_part(ctxs)),
                    ctx0_off=_write(carry["ctx0_off"], mb,
                                    to_host(_off_part(ctxs))))
            ck = "ctx0" if which == 0 else "ctx1"
            return dict(carry, **{ck: _write(carry[ck], mb, ctxs)})

        def _ctx_read(carry, mb, which):
            if which == 0 and off_on:
                return _join_off(_read(carry["ctx0"], mb),
                                 _read(carry["ctx0_stage"], carry["osel"]))
            return _read(carry["ctx0" if which == 0 else "ctx1"], mb)

        def _fetch(carry, fmb, frow):
            """End-of-slot FETCH: stage microbatch ``fmb``'s offloaded
            α-slice back on device in staging row ``frow``, one slot ahead
            of its B (``fmb == m`` encodes no-fetch)."""
            def do(off, stg):
                row = to_dev(_read(off, jnp.minimum(fmb, m - 1)))
                return jax.tree.map(
                    lambda s, n: jax.lax.dynamic_update_index_in_dim(
                        s, n.astype(s.dtype), frow, 0), stg, row)
            stg = jax.lax.cond(fmb < m, do, lambda off, s: s,
                               carry["ctx0_off"], carry["ctx0_stage"])
            return dict(carry, ctx0_stage=stg)

        def add_partial(acc, new, s=scale):
            if isinstance(new, dict):
                out = dict(acc)
                for k, v in new.items():
                    out[k] = add_partial(acc[k], v, s)
                return out
            return jax.tree.map(lambda a, b: a + s * b.astype(a.dtype),
                                acc, new)

        def add_layer(acc, i, new, s=scale):
            """acc leaves have leading layer dim; new is one layer's partial
            grad dict."""
            if isinstance(new, dict):
                out = dict(acc)
                for k, v in new.items():
                    out[k] = add_layer(acc[k], i, v, s)
                return out
            return acc.at[i].add(s * new.astype(acc.dtype))

        zx = lambda: jnp.zeros((bmb, seq, d_model), jnp.float32)

        def acts_out(**valid):
            """Per-act-stream (payload, flag) tuple, invalid by default."""
            return tuple(valid.get(s, (zx(), jnp.int32(0)))
                         for s in act_streams)

        def grads_out(**valid):
            return tuple(valid.get(s, (zx(), jnp.int32(0)))
                         for s in grad_streams)

        def _head_f(carry, mb, y):
            loss, hctx = M.head_fwd(head_p, tp_exec, y, _read(labels, mb),
                                    cfg)
            return dict(carry,
                        hctx=_write(carry["hctx"], mb, hctx),
                        loss=carry["loss"].at[mb].set(loss))

        def _head_b(carry, mb):
            gy, htape, hjoint = M.head_bwd_act(
                head_p, tp_exec, _read(carry["hctx"], mb), jnp.float32(1.0),
                cfg)
            carry = dict(carry,
                         htape=_write(carry["htape"], mb, htape),
                         ah=add_partial(carry["ah"], hjoint))
            return carry, gy

        # ---- branch bodies, specialised per partition signature --------
        def f_nop(carry, mb):
            return carry, acts_out()

        def b_nop(carry, mb):
            return carry, grads_out()

        def w_nop(carry, mb):
            return carry

        def make_defs(ops):
            chunk_f, chunk_b, _, chunk_w = ops

            def _f_chunk(carry, mb, which, src):
                cp = c0 if which == 0 else c1
                y, ctxs = chunk_f(which, cp, src)
                return _ctx_write(carry, mb, which, ctxs), y

            def f0(carry, mb):
                carry, y = _f_chunk(carry, mb, 0, _read(carry["x0"], mb))
                return carry, acts_out(x0=(y, jnp.int32(1)))

            def f0_embed(carry, mb):
                batch = ({"tokens": _read(tokens, mb)}
                         if cfg.frontend == "text"
                         else {"embeds": _read(tokens, mb)})
                x, _ = M.embed_fwd(embed_p, batch, cfg)
                carry, y = _f_chunk(carry, mb, 0, x)
                return carry, acts_out(x0=(y, jnp.int32(1)))

            def f0_turn(carry, mb):
                """vshape: chunk-0 output enters chunk 1 on the device."""
                carry, y = _f_chunk(carry, mb, 0, _read(carry["x0"], mb))
                carry = dict(carry, x1=_write(carry["x1"], mb, y))
                return carry, acts_out()

            def f0_send1(carry, mb):
                """parallel: chunk-0 output wraps to device 0's chunk 1."""
                carry, y = _f_chunk(carry, mb, 0, _read(carry["x0"], mb))
                return carry, acts_out(x1=(y, jnp.int32(1)))

            def f0_loss(carry, mb):
                """flat: last stage forward + loss head, no output."""
                carry, y = _f_chunk(carry, mb, 0, _read(carry["x0"], mb))
                return _head_f(carry, mb, y), acts_out()

            def f1(carry, mb):
                carry, y = _f_chunk(carry, mb, 1, _read(carry["x1"], mb))
                return carry, acts_out(x1=(y, jnp.int32(1)))

            def f1_loss(carry, mb):
                carry, y = _f_chunk(carry, mb, 1, _read(carry["x1"], mb))
                return _head_f(carry, mb, y), acts_out()

            def _b_chunk(carry, mb, which, gy):
                cp = c0 if which == 0 else c1
                ctxs = _ctx_read(carry, mb, which)
                gx, tapes, joints = chunk_b(which, cp, ctxs, gy)
                ck = "tape0" if which == 0 else "tape1"
                ak = "a0" if which == 0 else "a1"
                carry = dict(carry)
                carry[ck] = _write(carry[ck], mb, tapes)
                acc = carry[ak]
                for i, j in enumerate(joints):
                    acc = add_layer(acc, i, j)
                carry[ak] = acc
                return carry, gx

            def b0(carry, mb):
                carry, gx = _b_chunk(carry, mb, 0, _read(carry["g0"], mb))
                return carry, grads_out(g0=(gx, jnp.int32(1)))

            def b0_embed(carry, mb):
                carry, gx = _b_chunk(carry, mb, 0, _read(carry["g0"], mb))
                batch = ({"tokens": _read(tokens, mb)}
                         if cfg.frontend == "text"
                         else {"embeds": _read(tokens, mb)})
                _, ectx = M.embed_fwd(embed_p, batch, cfg)
                ge = M.embed_bwd_weight(embed_p, ectx, gx)
                carry = dict(carry, ae=add_partial(carry["ae"], ge))
                return carry, grads_out()

            def b0_loss(carry, mb):
                """flat: loss head backward + last stage backward."""
                carry, gy = _head_b(carry, mb)
                carry, gx = _b_chunk(carry, mb, 0, gy)
                return carry, grads_out(g0=(gx, jnp.int32(1)))

            def b1(carry, mb):
                carry, gx = _b_chunk(carry, mb, 1, _read(carry["g1"], mb))
                return carry, grads_out(g1=(gx, jnp.int32(1)))

            def b1_turn(carry, mb):
                """vshape: chunk-1 grad enters chunk 0 on the device."""
                carry, gx = _b_chunk(carry, mb, 1, _read(carry["g1"], mb))
                carry = dict(carry, g0=_write(carry["g0"], mb, gx))
                return carry, grads_out()

            def b1_send0(carry, mb):
                """parallel: chunk-1 grad wraps to device p-1's chunk 0."""
                carry, gx = _b_chunk(carry, mb, 1, _read(carry["g1"], mb))
                return carry, grads_out(g0=(gx, jnp.int32(1)))

            def b1_loss(carry, mb):
                carry, gy = _head_b(carry, mb)
                carry, gx = _b_chunk(carry, mb, 1, gy)
                return carry, grads_out(g1=(gx, jnp.int32(1)))

            def _w_chunk(carry, mb, which):
                ck = "tape0" if which == 0 else "tape1"
                ak = "a0" if which == 0 else "a1"
                gws = chunk_w(which, _read(carry[ck], mb))
                acc = carry[ak]
                for i, gw in enumerate(gws):
                    acc = add_layer(acc, i, gw)
                carry = dict(carry)
                carry[ak] = acc
                return carry

            def _w_head(carry, mb):
                gh = M.head_bwd_weight(_read(carry["htape"], mb))
                return dict(carry, ah=add_partial(carry["ah"], gh))

            def w0(carry, mb):
                return _w_chunk(carry, mb, 0)

            def w0_head(carry, mb):
                return _w_head(_w_chunk(carry, mb, 0), mb)

            def w1(carry, mb):
                return _w_chunk(carry, mb, 1)

            def w1_head(carry, mb):
                return _w_head(_w_chunk(carry, mb, 1), mb)

            fdefs = dict(f_nop=f_nop, f0=f0, f0_embed=f0_embed,
                         f0_turn=f0_turn, f0_send1=f0_send1, f0_loss=f0_loss,
                         f1=f1, f1_loss=f1_loss)
            bdefs = dict(b_nop=b_nop, b0=b0, b0_embed=b0_embed,
                         b0_loss=b0_loss, b1=b1, b1_turn=b1_turn,
                         b1_send0=b1_send0, b1_loss=b1_loss)
            wdefs = dict(w_nop=w_nop, w0=w0, w0_head=w0_head, w1=w1,
                         w1_head=w1_head)
            return fdefs, bdefs, wdefs

        defs_by_sig = [make_defs(ops) for ops in sig_ops]

        if ablate in ("compute", "both"):
            # --breakdown stubs: per-role buffer touch + emit, preserving
            # the dispatch arms, stream liveness and a loss data-dependence
            # (every exchange chain terminates in `loss`, so XLA keeps the
            # switch + ppermute skeleton) while dropping the model math.
            def _touch(out, src, emit=None, store=None, to_loss=False):
                def fn(carry, mb):
                    val = _read(carry[src], mb)
                    if store:
                        carry = dict(carry,
                                     **{store: _write(carry[store], mb,
                                                      val)})
                    if to_loss:
                        carry = dict(carry, loss=carry["loss"].at[mb].add(
                            jnp.sum(val)))
                    if emit is None:
                        return (carry, out()) if out else carry
                    return carry, out(**{emit: (val, jnp.int32(1))})
                return fn

            fdefs = dict(
                f_nop=f_nop,
                f0=_touch(acts_out, "x0", emit="x0"),
                f0_embed=_touch(acts_out, "x0", emit="x0"),
                f0_turn=_touch(acts_out, "x0", store="x1"),
                f0_send1=_touch(acts_out, "x0", emit="x1"),
                f0_loss=_touch(acts_out, "x0", to_loss=True),
                f1=_touch(acts_out, "x1", emit="x1"),
                f1_loss=_touch(acts_out, "x1", to_loss=True))
            bdefs = dict(
                b_nop=b_nop,
                b0=_touch(grads_out, "g0", emit="g0"),
                b0_embed=_touch(grads_out, "g0", to_loss=True),
                b0_loss=_touch(grads_out, "g0", emit="g0"),
                b1=_touch(grads_out, "g1", emit="g1"),
                b1_turn=_touch(grads_out, "g1", store="g0"),
                b1_send0=_touch(grads_out, "g1", emit="g0"),
                b1_loss=_touch(grads_out, "g1", emit="g1"))
            wdefs = {n: w_nop for n in SL.W_BRANCHES[pl.kind]}
            defs_by_sig = [(fdefs, bdefs, wdefs)] * len(sigs)

        # Per-signature branch lists: arm (sg, code) loops sg's layer counts.
        f_br = [[d[0][n] for n in SL.F_BRANCHES[pl.kind]]
                for d in defs_by_sig]
        b_br = [[d[1][n] for n in SL.B_BRANCHES[pl.kind]]
                for d in defs_by_sig]
        w_br = [[d[2][n] for n in SL.W_BRANCHES[pl.kind]]
                for d in defs_by_sig]

        # ---- braided composite F&B arms (paper §4, Fig. 1) --------------
        # A composite slot (both F and B active) lowers as ONE braided
        # chunk call instead of chunk_f-then-chunk_b, so each side's TP ring
        # hops interleave with the partner's matmuls.  The per-branch
        # source/emit plumbing is factored out of the f*/b* branch bodies
        # above so the braided arm reproduces them exactly.
        F_SRC = {"f0": "x0", "f0_embed": None, "f0_turn": "x0",
                 "f0_send1": "x0", "f0_loss": "x0", "f1": "x1",
                 "f1_loss": "x1"}
        F_CHUNK = {"f0": 0, "f0_embed": 0, "f0_turn": 0, "f0_send1": 0,
                   "f0_loss": 0, "f1": 1, "f1_loss": 1}
        B_CHUNK = {"b0": 0, "b0_embed": 0, "b0_loss": 0, "b1": 1,
                   "b1_turn": 1, "b1_send0": 1, "b1_loss": 1}

        def _embed_x(mb):
            batch = ({"tokens": _read(tokens, mb)} if cfg.frontend == "text"
                     else {"embeds": _read(tokens, mb)})
            x, _ = M.embed_fwd(embed_p, batch, cfg)
            return x

        def _f_emit(name, carry, mb, y):
            if name in ("f0", "f0_embed"):
                return carry, acts_out(x0=(y, jnp.int32(1)))
            if name in ("f0_send1", "f1"):
                return carry, acts_out(x1=(y, jnp.int32(1)))
            if name == "f0_turn":
                return dict(carry, x1=_write(carry["x1"], mb, y)), acts_out()
            return _head_f(carry, mb, y), acts_out()    # f0_loss / f1_loss

        def _b_gy(name, carry, mb):
            if name in ("b0_loss", "b1_loss"):
                return _head_b(carry, mb)
            return carry, _read(carry["g0" if B_CHUNK[name] == 0 else "g1"],
                                mb)

        def _b_emit(name, carry, mb, gx):
            if name == "b0_embed":
                batch = ({"tokens": _read(tokens, mb)}
                         if cfg.frontend == "text"
                         else {"embeds": _read(tokens, mb)})
                _, ectx = M.embed_fwd(embed_p, batch, cfg)
                ge = M.embed_bwd_weight(embed_p, ectx, gx)
                return (dict(carry, ae=add_partial(carry["ae"], ge)),
                        grads_out())
            if name in ("b0", "b0_loss", "b1_send0"):
                return carry, grads_out(g0=(gx, jnp.int32(1)))
            if name == "b1_turn":
                return (dict(carry, g0=_write(carry["g0"], mb, gx)),
                        grads_out())
            return carry, grads_out(g1=(gx, jnp.int32(1)))   # b1 / b1_loss

        def braided_fb(sg, fname, bname):
            chunk_fb = sig_ops[sg][2]
            fck, bck = F_CHUNK[fname], B_CHUNK[bname]
            fcp = c0 if fck == 0 else c1
            bcp = c0 if bck == 0 else c1
            tape_key = "tape0" if bck == 0 else "tape1"
            ak = "a0" if bck == 0 else "a1"
            src = F_SRC[fname]

            def fb(carry, fmb, bmb_):
                x = _embed_x(fmb) if src is None else _read(carry[src], fmb)
                ctxs_in = _ctx_read(carry, bmb_, bck)
                carry, gy = _b_gy(bname, carry, bmb_)
                y, ctxs, gx, tapes, joints = chunk_fb(fck, bck, fcp, x, bcp,
                                                      ctxs_in, gy)
                carry = dict(_ctx_write(carry, fmb, fck, ctxs))
                carry[tape_key] = _write(carry[tape_key], bmb_, tapes)
                acc = carry[ak]
                for i, j in enumerate(joints):
                    acc = add_layer(acc, i, j)
                carry[ak] = acc
                carry, acts = _f_emit(fname, carry, fmb, y)
                carry, grads = _b_emit(bname, carry, bmb_, gx)
                return carry, acts, grads
            return fb

        # ---- slot body --------------------------------------------------
        me = jax.lax.axis_index(stage_axis)
        if wiring["wrap"]:
            perm_up = [(s, (s + 1) % p) for s in range(p)]
            perm_dn = [(s, (s - 1) % p) for s in range(p)]
        else:
            perm_up = [(s, s + 1) for s in range(p - 1)]
            perm_dn = [(s, s - 1) for s in range(1, p)]
        perm_of = {"up": perm_up, "dn": perm_dn}

        def _exchange(carry, acts, grads, fmb, bmb_):
            # exchange.  mb indices are sent +1 so that the zeros a device
            # receives when it has no upstream decode as "invalid" and land
            # in the scratch row m.
            stream = {}
            for s, (val, ok) in zip(act_streams, acts):
                stream[s] = (val, jnp.where(ok > 0, fmb + 1, 0))
            for s, (val, ok) in zip(grad_streams, grads):
                stream[s] = (val, jnp.where(ok > 0, bmb_ + 1, 0))

            def send(payload, perm):
                return jax.tree.map(
                    lambda x: jax.lax.ppermute(x, stage_axis, perm), payload)

            for names, perm in ((wiring["up"], perm_up),
                                (wiring["dn"], perm_dn)):
                rx = send(tuple(stream[s] for s in names), perm)
                for s, (val, mbidx) in zip(names, rx):
                    row = jnp.where(mbidx > 0, mbidx - 1, m)
                    carry = dict(carry,
                                 **{s: _write(carry[s], row, val)})
            return carry

        # Generic lowerings dispatch through compact per-slot tables over
        # the distinct (role code, partition signature) combinations present
        # in the grid — uniform partitions (one signature) reduce to the
        # plain per-role switch.
        n_slots = codes_np.shape[0]

        def _sig_tab(col, arms_by_sig):
            keys = sorted({(int(codes_np[t, d, col]), int(sig_id[d]))
                           for t in range(n_slots) for d in range(p)})
            tab = np.array([[keys.index((int(codes_np[t, d, col]),
                                         int(sig_id[d])))
                             for d in range(p)]
                            for t in range(n_slots)], np.int32)
            return [arms_by_sig[sg][c] for c, sg in keys], tab

        if not fuse:
            f_arms, f_tab = _sig_tab(0, f_br)
            b_arms, b_tab = _sig_tab(2, b_br)
            w_arms, w_tab = _sig_tab(4, w_br)

        def generic_slot(carry, xs_t):
            if off_on:
                codes_t, ft, bt, wt, off_t = xs_t
                carry = dict(carry, osel=off_t[me, 2])
            else:
                codes_t, ft, bt, wt = xs_t
            my = codes_t[me]
            fmb, bmb_, wmb = my[1], my[3], my[5]
            carry, acts = jax.lax.switch(ft[me], f_arms, carry, fmb)
            carry, grads = jax.lax.switch(bt[me], b_arms, carry, bmb_)
            carry = jax.lax.switch(wt[me], w_arms, carry, wmb)
            if do_exchange:
                carry = _exchange(carry, acts, grads, fmb, bmb_)
            if off_on:
                carry = _fetch(carry, off_t[me, 0], off_t[me, 1])
            return carry, None

        def generic_braid_slot(carry, xs_t):
            """Generic lowering under braid_tp: F and B dispatch through one
            joint switch over the grid's distinct static (F, B, signature)
            triples so composite pairs can lower as a single braided call."""
            if off_on:
                codes_t, pc_t, wt, off_t = xs_t
                carry = dict(carry, osel=off_t[me, 2])
            else:
                codes_t, pc_t, wt = xs_t
            my = codes_t[me]
            fmb, bmb_, wmb = my[1], my[3], my[5]
            carry, acts, grads = jax.lax.switch(pc_t[me], pair_arms, carry,
                                                fmb, bmb_)
            carry = jax.lax.switch(wt[me], w_arms, carry, wmb)
            if do_exchange:
                carry = _exchange(carry, acts, grads, fmb, bmb_)
            if off_on:
                carry = _fetch(carry, off_t[me, 0], off_t[me, 1])
            return carry, None

        if braid and not fuse:
            fb_names = SL.F_BRANCHES[pl.kind]
            bb_names = SL.B_BRANCHES[pl.kind]
            pairs = sorted({(int(codes_np[t, d, 0]), int(codes_np[t, d, 2]),
                             int(sig_id[d]))
                            for t in range(n_slots) for d in range(p)})
            pair_codes = np.array(
                [[pairs.index((int(codes_np[t, d, 0]),
                               int(codes_np[t, d, 2]), int(sig_id[d])))
                  for d in range(p)]
                 for t in range(n_slots)], np.int32)

            def pair_arm(fc, bc, sg):
                if fc > 0 and bc > 0:
                    return braided_fb(sg, fb_names[fc], bb_names[bc])

                def seq(carry, fmb, bmb_):
                    carry, acts = f_br[sg][fc](carry, fmb)
                    carry, grads = b_br[sg][bc](carry, bmb_)
                    return carry, acts, grads
                return seq

            pair_arms = [pair_arm(*k) for k in pairs]

        def run_segment(carry, seg):
            """Fused lowering of one periodic segment: branch bodies
            composed at trace time from each phase's static role rows, one
            scan over its iterations (mb indices + static receive rows are
            the only scanned values), dead streams elided per phase from
            the exchange.  The scan body unrolls the segment's ``period``
            phases, so steady-state braids (F,BW,F,BW,... in 1f1b and the
            zero-bubble family) trace one loop body instead of one inlined
            program per slot."""
            k = seg.period

            def arm_of(fc, bc, wc, sg):
                wf = w_br[sg][wc]
                if braid and fc > 0 and bc > 0:
                    fb = braided_fb(sg, SL.F_BRANCHES[pl.kind][fc],
                                    SL.B_BRANCHES[pl.kind][bc])

                    def braided_arm(carry, mb3):
                        carry, acts, grads = fb(carry, mb3[0], mb3[1])
                        carry = wf(carry, mb3[2])
                        return (carry, tuple(v for v, _ in acts),
                                tuple(v for v, _ in grads))
                    return braided_arm
                ff = f_br[sg][fc]
                bf = b_br[sg][bc]

                def arm(carry, mb3):
                    carry, acts = ff(carry, mb3[0])
                    carry, grads = bf(carry, mb3[1])
                    carry = wf(carry, mb3[2])
                    return (carry, tuple(v for v, _ in acts),
                            tuple(v for v, _ in grads))
                return arm

            arms, row_id = [], []
            for ph in seg.phases:
                rows = [(r, int(sig_id[d])) for d, r in enumerate(ph)]
                distinct = list(dict.fromkeys(rows))
                arms.append([arm_of(*r, sg) for r, sg in distinct])
                row_id.append(jnp.asarray(
                    np.array([distinct.index(r) for r in rows], np.int32)))

            # Offload plan rows for this segment, phase-sliced like mbs;
            # phases with no fetch in any iteration skip the fetch body
            # statically (warmup/cooldown phases stay exactly the α=0 code
            # apart from the scalar staging-row selector update).
            offs = off_plan_np[seg.start:seg.stop] if off_on else None
            fetch_ph = ([bool((offs[j::k, :, 0] < m).any())
                         for j in range(k)] if off_on else [False] * k)

            def one_phase(carry, j, mb_t, rr_t, off_t=None):
                # mb_t: (p, 3), rr_t: (p, n_live of phase j)
                if off_on:
                    carry = dict(carry, osel=off_t[me, 2])
                my = mb_t[me]
                if len(arms[j]) == 1:
                    carry, acts, grads = arms[j][0](carry, my)
                else:
                    carry, acts, grads = jax.lax.switch(
                        row_id[j][me], arms[j], carry, my)
                if do_exchange:
                    vals = dict(zip(act_streams, acts))
                    vals.update(zip(grad_streams, grads))
                    i = 0
                    for names, perm in ((seg.live[j][0], perm_of["up"]),
                                        (seg.live[j][1], perm_of["dn"])):
                        for s in names:
                            rx = jax.lax.ppermute(vals[s], stage_axis, perm)
                            carry = dict(carry,
                                         **{s: _write(carry[s],
                                                      rr_t[me, i], rx)})
                            i += 1
                if fetch_ph[j]:
                    carry = _fetch(carry, off_t[me, 0], off_t[me, 1])
                return carry

            mbs = codes_np[seg.start:seg.stop, :, 1::2]
            rr = SL.recv_rows(codes_np, seg, pl.kind, m)
            if seg.n_iters == 1:
                for j in range(k):
                    carry = one_phase(
                        carry, j, jnp.asarray(mbs[j]), jnp.asarray(rr[j][0]),
                        jnp.asarray(offs[j]) if off_on else None)
                return carry

            def seg_body(carry, xs):
                for j in range(k):
                    carry = one_phase(carry, j, xs[j], xs[k + j],
                                      xs[2 * k + j] if off_on else None)
                return carry, None

            xs = (tuple(jnp.asarray(mbs[j::k]) for j in range(k))
                  + tuple(jnp.asarray(r) for r in rr))
            if off_on:
                xs += tuple(jnp.asarray(offs[j::k]) for j in range(k))
            carry, _ = jax.lax.scan(seg_body, carry, xs)
            return carry

        if fuse:
            for seg in SL.segment_grid(codes_np, pl.kind):
                carry = run_segment(carry, seg)
        elif braid:
            xs = (jnp.asarray(codes_np), jnp.asarray(pair_codes),
                  jnp.asarray(w_tab))
            if off_on:
                xs += (jnp.asarray(off_plan_np),)
            carry, _ = jax.lax.scan(generic_braid_slot, carry, xs)
        else:
            xs = (jnp.asarray(codes_np), jnp.asarray(f_tab),
                  jnp.asarray(b_tab), jnp.asarray(w_tab))
            if off_on:
                xs += (jnp.asarray(off_plan_np),)
            carry, _ = jax.lax.scan(generic_slot, carry, xs)
        loss = jax.lax.psum(carry["loss"].sum() * scale, stage_axis)
        g0 = jax.tree.map(lambda a: a[None], carry["a0"])
        g1 = (jax.tree.map(lambda a: a[None], carry["a1"])
              if two_chunks else {})
        ge = jax.tree.map(lambda a: jax.lax.psum(a, stage_axis), carry["ae"])
        gh = jax.tree.map(lambda a: jax.lax.psum(a, stage_axis), carry["ah"])
        return loss, g0, g1, ge, gh

    return run


def stage_param_specs(param_trees, *, stage_axis: str = "stage",
                      model_axis: Optional[str] = None,
                      expert_axis: Optional[str] = None) -> dict:
    """PartitionSpec dict for the stage-layout state params
    ``{"c0", "c1", "embed", "head"}`` given (c0, c1, embed, head) trees."""
    return {"c0": tp_specs(param_trees[0], model_axis, stage_axis, lead=2,
                           expert_axis=expert_axis),
            "c1": tp_specs(param_trees[1], model_axis, stage_axis, lead=2,
                           expert_axis=expert_axis),
            "embed": tp_specs(param_trees[2], None, None),
            "head": tp_specs(param_trees[3], model_axis, None)}


def build_pipeline_step(cfg: ModelConfig, tables, pl: Placement, mesh: Mesh,
                        m: int, mb_shape, param_trees, *,
                        stage_axis: str = "stage",
                        model_axis: Optional[str] = None,
                        expert_axis: Optional[str] = None,
                        fuse_slots: bool = True,
                        ablate: Optional[str] = None,
                        braid_tp: bool = False,
                        part=None,
                        offload_alpha: float = 0.0):
    """Returns a jitted SPMD function
    ``step(c0, c1, embed_p, head_p, tokens, labels) -> (loss, g0, g1,
    g_embed, g_head)`` executing the schedule over the ``stage`` (and
    optionally ``model``) mesh axes, for any placement kind
    (flat / parallel / vshape).

    mb_shape: (mb_batch, seq) of one microbatch.
    param_trees: (c0, c1, embed_p, head_p) — global (unsharded) pytrees or
    ShapeDtypeStructs; used to derive shard specs and local buffer shapes.
    For flat placements c1 is the empty pytree ``{}``.

    ``fuse_slots`` selects the fused segment lowering (default) vs the
    generic one-switch-per-slot scan; ``ablate`` builds the benchmark-only
    cost-breakdown variants; ``braid_tp`` routes composite F&B slots
    through the braided overlap-aware chunk executor; ``offload_alpha``
    enables the §4.4 activation-offload lowering (see
    ``_pipeline_program``).
    """
    run = _pipeline_program(cfg, tables, pl, mesh, m, mb_shape, param_trees,
                            stage_axis=stage_axis, model_axis=model_axis,
                            expert_axis=expert_axis,
                            fuse=fuse_slots, ablate=ablate, braid_tp=braid_tp,
                            part=part, offload_alpha=offload_alpha)
    rep = P()
    sp = stage_param_specs(param_trees, stage_axis=stage_axis,
                           model_axis=model_axis, expert_axis=expert_axis)
    fn = shard_map(
        run, mesh=mesh,
        in_specs=(sp["c0"], sp["c1"], sp["embed"], sp["head"], rep, rep),
        out_specs=(rep, sp["c0"], sp["c1"], sp["embed"], sp["head"]),
        check_rep=False,
    )
    return jax.jit(fn)


def _dup_factors(param_trees, mesh: Mesh, *, stage_axis: str,
                 model_axis: Optional[str],
                 expert_axis: Optional[str] = None) -> dict:
    """Per-leaf replica counts of the *gradients* across the (stage, expert,
    model) mesh axes, keyed like the state params dict.  Block grads are
    unique per stage and TP/EP-sharded where the param is; embed/head grads
    come out of the program psum'd over ``stage`` so every stage row holds a
    full copy.  Used to weight local sum-of-squares so the global grad norm
    counts every element exactly once."""
    p = mesh.shape[stage_axis]
    tp_size = mesh.shape[model_axis] if model_axis else 1
    ep_size = mesh.shape[expert_axis] if expert_axis else 1

    def group(tree, lead, base):
        def one(path, leaf):
            name = None
            for k in reversed(path):
                if hasattr(k, "key"):
                    name = k.key
                    break
            ax = (_tp_axis_of(name, leaf.ndim - lead)
                  if model_axis else None)
            eax = (_ep_axis_of(name, leaf.ndim - lead)
                   if expert_axis else None)
            return (base * (1 if ax is not None else tp_size)
                    * (1 if eax is not None else ep_size))
        return jax.tree_util.tree_map_with_path(one, tree)

    return {"c0": group(param_trees[0], 2, 1),
            "c1": group(param_trees[1], 2, 1),
            "embed": jax.tree.map(lambda _: p * tp_size * ep_size,
                                  param_trees[2]),
            "head": group(param_trees[3], 0, p)}


def build_pipeline_train_step(cfg: ModelConfig, tables, pl: Placement,
                              mesh: Mesh, m: int, mb_shape, param_trees,
                              oc: OptConfig, *,
                              stage_axis: str = "stage",
                              model_axis: Optional[str] = None,
                              expert_axis: Optional[str] = None,
                              fuse_slots: bool = True,
                              braid_tp: bool = False,
                              part=None,
                              offload_alpha: float = 0.0):
    """Fused pipeline *train* step: schedule execution, global-norm
    clipping and the AdamW update all under one ``shard_map`` — stacked
    params and optimizer moments never leave the mesh between steps.

    Returns a jitted ``train(params, opt, tokens, labels) -> (params', opt',
    loss, gnorm)`` where ``params`` is the stage-layout dict
    ``{"c0", "c1", "embed", "head"}`` (c1 = {} for flat placements) and
    ``opt = {"mu": like params, "nu": like params, "step": int32[]}``.

    The global grad norm is assembled from per-device partial sums weighted
    by each leaf's replica count (`_dup_factors`), then psum'd over the
    stage (and model) axes, so clipping matches the host
    ``optim.adamw_update`` on canonical grads up to float reassociation.
    Weight decay applies to leaves whose *canonical* rank is >= 2 (the two
    stacking dims of c0/c1 don't count).
    """
    run = _pipeline_program(cfg, tables, pl, mesh, m, mb_shape, param_trees,
                            stage_axis=stage_axis, model_axis=model_axis,
                            expert_axis=expert_axis,
                            fuse=fuse_slots, braid_tp=braid_tp, part=part,
                            offload_alpha=offload_alpha)
    sp = stage_param_specs(param_trees, stage_axis=stage_axis,
                           model_axis=model_axis, expert_axis=expert_axis)
    ospec = {"mu": sp, "nu": sp, "step": P()}
    dup = _dup_factors(param_trees, mesh, stage_axis=stage_axis,
                       model_axis=model_axis, expert_axis=expert_axis)
    lead = {"c0": 2, "c1": 2, "embed": 0, "head": 0}
    axes = tuple(a for a in (stage_axis, expert_axis, model_axis)
                 if a is not None)

    def train(params, opt, tokens, labels):
        loss, g0, g1, ge, gh = run(params["c0"], params["c1"],
                                   params["embed"], params["head"],
                                   tokens, labels)
        grads = {"c0": g0, "c1": g1, "embed": ge, "head": gh}
        sq = sum((jnp.sum(jnp.square(g.astype(jnp.float32))) / d
                  for g, d in zip(jax.tree.leaves(grads),
                                  jax.tree.leaves(dup))),
                 start=jnp.float32(0.0))
        gnorm = jnp.sqrt(jax.lax.psum(sq, axes))
        scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-12))
        lr, c1b, c2b = adamw_scalars(oc, opt["step"])

        p_flat, tdef = jax.tree.flatten(params)
        g_flat = tdef.flatten_up_to(grads)
        mu_flat = tdef.flatten_up_to(opt["mu"])
        nu_flat = tdef.flatten_up_to(opt["nu"])
        ld_flat = tdef.flatten_up_to(
            {k: jax.tree.map(lambda _: lead[k], v)
             for k, v in params.items()})
        out = [adamw_leaf(pp, g * scale, mu, nu, lr, c1b, c2b, oc,
                          decay=(pp.ndim - ld) >= 2)
               for pp, g, mu, nu, ld
               in zip(p_flat, g_flat, mu_flat, nu_flat, ld_flat)]
        unflat = lambda i: jax.tree.unflatten(tdef, [o[i] for o in out])
        opt2 = {"mu": unflat(1), "nu": unflat(2), "step": opt["step"] + 1}
        return unflat(0), opt2, loss, gnorm

    rep = P()
    fn = shard_map(
        train, mesh=mesh,
        in_specs=(sp, ospec, rep, rep),
        out_specs=(sp, ospec, rep, rep),
        check_rep=False,
    )
    return jax.jit(fn)
