"""SPMD pipeline executor: shard_map over a real ``stage`` mesh axis.

One scanned program executes every device's instruction stream in lockstep
slots.  Per slot each device

  1. selects its instruction codes (``lax.switch`` over F/B/W sub-steps;
     a braided F&B block is simply a slot whose F- and B-parts are both
     active — inside one jitted slot their computations are data-independent,
     which is precisely the legal-overlap window the paper engineers),
  2. exchanges boundary tensors with its neighbours via two ``ppermute``s:
     shift +1 carries chunk-0 activations and chunk-1 gradients (the "V"
     down-sweep), shift −1 carries chunk-1 activations and chunk-0 gradients.

Scope: V-shape placements (the paper's schedule family), uniform layer
stacks (``n_layers % 2p == 0``), TP optionally composed via a ``model`` mesh
axis.  Heterogeneous architectures run through ``pipeline.reference`` and the
pjit path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.simulator import Placement
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.pipeline import slots as SL
from repro.tp.context import TPContext


def stack_stage_params(params, cfg: ModelConfig, p: int):
    """Canonical params -> (chunk0, chunk1) stacked with leading (p, L_vs)
    dims + embed/head.  chunk0 vs s = device s; chunk1 vs 2p-1-s = device s,
    i.e. chunk1 stages are stacked in *device* order (reversed vs order)."""
    n = cfg.n_layers
    assert n % (2 * p) == 0, f"SPMD executor needs n_layers % 2p == 0 ({n}, {p})"
    lvs = n // (2 * p)
    blocks = params["blocks"]

    def stack(layers):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)

    c0 = stack([stack(blocks[s * lvs:(s + 1) * lvs]) for s in range(p)])
    # device s hosts vs 2p-1-s -> layers [(2p-1-s)*lvs : (2p-s)*lvs]
    c1 = stack([stack(blocks[(2 * p - 1 - s) * lvs:(2 * p - s) * lvs])
                for s in range(p)])
    return c0, c1, lvs


def unstack_stage_grads(g0, g1, cfg: ModelConfig, p: int, lvs: int):
    """Inverse of ``stack_stage_params`` for the gradient pytrees."""
    blocks = [None] * cfg.n_layers
    for s in range(p):
        for i in range(lvs):
            blocks[s * lvs + i] = jax.tree.map(lambda x: x[s, i], g0)
            blocks[(2 * p - 1 - s) * lvs + i] = jax.tree.map(
                lambda x: x[s, i], g1)
    return blocks


def _zeros_like_tree(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), tree)


# ---------------------------------------------------------------------------
# Megatron-style TP sharding rules for the unit-mode (shard_map) params.
# Column-parallel: qkv / up projections split their output dim; row-parallel:
# down/out projections split their input dim; norm gains, routers and small
# core params are replicated; the LM head is vocab-parallel.
# ---------------------------------------------------------------------------

def _tp_axis_of(name: str, base_ndim: int):
    """TP shard axis (negative, counted from the right) for a named param,
    or None if replicated.  Column-parallel projections split their output
    dim, row-parallel split their input dim, heads axes shard for the
    head-blocked mLSTM mixers; routers / norms / small cores replicate.
    sLSTM in-projections interleave four gate blocks and stay replicated
    (DESIGN.md §Arch-applicability)."""
    col2 = {"wg", "wu", "w1", "w_in_x", "w_in_z", "w_upx", "w_upz", "w_lm"}
    row2 = {"wo", "wd", "w2", "w_out", "w_down"}
    if name in ("wq", "wk", "wv"):
        return -3 if base_ndim >= 3 else -1              # mlstm heads / attn
    if name in ("wi", "wf"):
        return -2                                        # mlstm gate heads
    if name in col2:
        return -1
    if name in row2:
        return -2
    return None


def tp_specs(tree, model_axis: Optional[str], stage_axis: Optional[str],
             lead: int = 0):
    """PartitionSpec tree for a params pytree.  ``lead`` extra leading dims
    (stage stack + per-vs layer stack) precede the parameter's own dims; if
    ``stage_axis`` is given it names the first of them."""
    def one(path, leaf):
        name = None
        for k in reversed(path):
            if hasattr(k, "key"):
                name = k.key
                break
        spec = [None] * leaf.ndim
        if stage_axis is not None:
            spec[0] = stage_axis
        ax = _tp_axis_of(name, leaf.ndim - lead) if model_axis else None
        if ax is not None:
            spec[leaf.ndim + ax] = model_axis
        return P(*spec)
    return jax.tree_util.tree_map_with_path(one, tree)


def _stackm(tree, m):
    return jax.tree.map(
        lambda x: jnp.zeros((m,) + x.shape, x.dtype), tree)


def _read(buf, mb):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, mb, 0, keepdims=False), buf)


def _write(buf, mb, val):
    return jax.tree.map(
        lambda a, v: jax.lax.dynamic_update_index_in_dim(
            a, v.astype(a.dtype), mb, 0), buf, val)


def _local_sds(tree, tp_size: int, lead: int, strip: int):
    """ShapeDtypeStructs of the per-device shards: drop ``strip`` leading
    (stage) dims and divide TP-ruled axes by ``tp_size``."""
    def one(path, leaf):
        name = None
        for k in reversed(path):
            if hasattr(k, "key"):
                name = k.key
                break
        shape = list(leaf.shape[strip:])
        ax = _tp_axis_of(name, leaf.ndim - lead)
        if ax is not None and tp_size > 1:
            shape[ax] = shape[ax] // tp_size
        return jax.ShapeDtypeStruct(tuple(shape), leaf.dtype)
    return jax.tree_util.tree_map_with_path(one, tree)


def build_pipeline_step(cfg: ModelConfig, tables, pl: Placement, mesh: Mesh,
                        m: int, mb_shape, param_trees, *,
                        stage_axis: str = "stage",
                        model_axis: Optional[str] = None):
    """Returns a jitted SPMD function
    ``step(c0, c1, embed_p, head_p, tokens, labels) -> (loss, g0, g1,
    g_embed, g_head)`` executing the schedule over the ``stage`` (and
    optionally ``model``) mesh axes.

    mb_shape: (mb_batch, seq) of one microbatch.
    param_trees: (c0, c1, embed_p, head_p) — global (unsharded) pytrees or
    ShapeDtypeStructs; used to derive shard specs and local buffer shapes.
    """
    p = pl.p
    grid = SL.to_slots(tables, pl)
    codes = jnp.asarray(SL.encode(grid, pl))            # (L, p, 6)
    tp = TPContext(axis=model_axis,
                   size=(mesh.shape[model_axis] if model_axis else 1))
    specs0 = cfg.layers[:cfg.n_layers // (2 * p)]       # uniform stacks
    specs1 = specs0
    bmb, seq = mb_shape
    d_model = cfg.d_model
    scale = 1.0 / m
    rope = M._rope_for(cfg, seq)

    def chunk_f(cparams, x, tpc=tp):
        layers = [jax.tree.map(lambda a: a[i], cparams)
                  for i in range(len(specs0))]
        return M.chunk_fwd(layers, tpc, x, rope, specs0, cfg)

    def chunk_b(cparams, ctxs, gy, tpc=tp):
        layers = [jax.tree.map(lambda a: a[i], cparams)
                  for i in range(len(specs0))]
        return M.chunk_bwd_act(layers, tpc, ctxs, gy, specs0, cfg)

    def chunk_w(tapes):
        return M.chunk_bwd_weight(tapes, specs0)

    # --- trace shapes for context/tape buffers --------------------------
    x_sds = jax.ShapeDtypeStruct((bmb, seq, d_model), jnp.float32)
    tok_sds = (jax.ShapeDtypeStruct((bmb, seq), jnp.int32)
               if cfg.frontend == "text"
               else jax.ShapeDtypeStruct((bmb, seq, d_model), jnp.float32))
    lab_sds = jax.ShapeDtypeStruct((bmb, seq), jnp.int32)

    # Buffer shapes are traced with an identity TPContext over the *local*
    # shard shapes — collectives preserve shapes, so the unit-mode buffers
    # match (eval_shape cannot bind mesh axis names).
    tp0 = TPContext()
    cp_sds = _local_sds(param_trees[0], tp.size, lead=2, strip=1)
    _, ctx_sds = jax.eval_shape(lambda c, x: chunk_f(c, x, tp0),
                                cp_sds, x_sds)
    gx_sds, tape_sds, joint_sds = jax.eval_shape(
        lambda c, cx, g: chunk_b(c, cx, g, tp0), cp_sds, ctx_sds, x_sds)
    head_sds = _local_sds(param_trees[3], tp.size, lead=0, strip=0)
    _, hctx_sds = jax.eval_shape(
        lambda hp, x, lab: M.head_fwd(hp, tp0, x, lab, cfg),
        head_sds, x_sds, lab_sds)
    _, htape_sds, hjoint_sds = jax.eval_shape(
        lambda hp, c: M.head_bwd_act(hp, tp0, c, jnp.float32(1.0), cfg),
        head_sds, hctx_sds)

    def zeros_of(sds_tree, lead=None):
        return jax.tree.map(
            lambda s: jnp.zeros(((lead,) + s.shape) if lead else s.shape,
                                s.dtype), sds_tree)

    def run(c0, c1, embed_p, head_p, tokens, labels):
        """Per-device body (inside shard_map).  c0/c1 carry a leading
        stage dim of 1."""
        c0 = jax.tree.map(lambda a: a[0], c0)
        c1 = jax.tree.map(lambda a: a[0], c1)
        carry = {
            "x0": jnp.zeros((m + 1, bmb, seq, d_model), jnp.float32),
            "x1": jnp.zeros((m + 1, bmb, seq, d_model), jnp.float32),
            "g0": jnp.zeros((m + 1, bmb, seq, d_model), jnp.float32),
            "g1": jnp.zeros((m + 1, bmb, seq, d_model), jnp.float32),
            "ctx0": zeros_of(ctx_sds, m), "ctx1": zeros_of(ctx_sds, m),
            "tape0": zeros_of(tape_sds, m), "tape1": zeros_of(tape_sds, m),
            "hctx": zeros_of(hctx_sds, m), "htape": zeros_of(htape_sds, m),
            "loss": jnp.zeros((m,), jnp.float32),
            "a0": _zeros_like_tree(c0), "a1": _zeros_like_tree(c1),
            "ae": _zeros_like_tree(embed_p),
            "ah": _zeros_like_tree(head_p),
        }

        def add_partial(acc, new, s=scale):
            if isinstance(new, dict):
                out = dict(acc)
                for k, v in new.items():
                    out[k] = add_partial(acc[k], v, s)
                return out
            return jax.tree.map(lambda a, b: a + s * b.astype(a.dtype),
                                acc, new)

        def add_layer(acc, i, new, s=scale):
            """acc leaves have leading layer dim; new is one layer's partial
            grad dict."""
            if isinstance(new, dict):
                out = dict(acc)
                for k, v in new.items():
                    out[k] = add_layer(acc[k], i, v, s)
                return out
            return acc.at[i].add(s * new.astype(acc.dtype))

        # ---- F branches -------------------------------------------------
        def f_nop(carry, mb):
            z = jnp.zeros((bmb, seq, d_model), jnp.float32)
            return carry, z, jnp.int32(0), z, jnp.int32(0)

        def _f_chunk0(carry, mb, src):
            y, ctxs = chunk_f(c0, src)
            carry = dict(carry, ctx0=_write(carry["ctx0"], mb, ctxs))
            return carry, y

        def f0(carry, mb):
            carry, y = _f_chunk0(carry, mb, _read(carry["x0"], mb))
            z = jnp.zeros_like(y)
            return carry, y, jnp.int32(1), z, jnp.int32(0)

        def f0_embed(carry, mb):
            batch = ({"tokens": _read(tokens, mb)} if cfg.frontend == "text"
                     else {"embeds": _read(tokens, mb)})
            x, _ = M.embed_fwd(embed_p, batch, cfg)
            carry, y = _f_chunk0(carry, mb, x)
            z = jnp.zeros_like(y)
            return carry, y, jnp.int32(1), z, jnp.int32(0)

        def f0_turn(carry, mb):
            carry, y = _f_chunk0(carry, mb, _read(carry["x0"], mb))
            carry = dict(carry, x1=_write(carry["x1"], mb, y))
            z = jnp.zeros_like(y)
            return carry, z, jnp.int32(0), z, jnp.int32(0)

        def f1(carry, mb):
            y, ctxs = chunk_f(c1, _read(carry["x1"], mb))
            carry = dict(carry, ctx1=_write(carry["ctx1"], mb, ctxs))
            z = jnp.zeros_like(y)
            return carry, z, jnp.int32(0), y, jnp.int32(1)

        def f1_loss(carry, mb):
            y, ctxs = chunk_f(c1, _read(carry["x1"], mb))
            loss, hctx = M.head_fwd(head_p, tp, y, _read(labels, mb), cfg)
            carry = dict(carry,
                         ctx1=_write(carry["ctx1"], mb, ctxs),
                         hctx=_write(carry["hctx"], mb, hctx),
                         loss=carry["loss"].at[mb].set(loss))
            z = jnp.zeros((bmb, seq, d_model), jnp.float32)
            return carry, z, jnp.int32(0), z, jnp.int32(0)

        # ---- B branches -------------------------------------------------
        def b_nop(carry, mb):
            z = jnp.zeros((bmb, seq, d_model), jnp.float32)
            return carry, z, jnp.int32(0), z, jnp.int32(0)

        def _b_chunk(carry, mb, which, gy):
            cp = c0 if which == 0 else c1
            ctxs = _read(carry["ctx0" if which == 0 else "ctx1"], mb)
            gx, tapes, joints = chunk_b(cp, ctxs, gy)
            ck = "tape0" if which == 0 else "tape1"
            ak = "a0" if which == 0 else "a1"
            carry = dict(carry)
            carry[ck] = _write(carry[ck], mb, tapes)
            acc = carry[ak]
            for i, j in enumerate(joints):
                acc = add_layer(acc, i, j)
            carry[ak] = acc
            return carry, gx

        def b0(carry, mb):
            carry, gx = _b_chunk(carry, mb, 0, _read(carry["g0"], mb))
            z = jnp.zeros_like(gx)
            return carry, z, jnp.int32(0), gx, jnp.int32(1)

        def b0_embed(carry, mb):
            carry, gx = _b_chunk(carry, mb, 0, _read(carry["g0"], mb))
            batch = ({"tokens": _read(tokens, mb)} if cfg.frontend == "text"
                     else {"embeds": _read(tokens, mb)})
            _, ectx = M.embed_fwd(embed_p, batch, cfg)
            ge = M.embed_bwd_weight(embed_p, ectx, gx)
            carry = dict(carry, ae=add_partial(carry["ae"], ge))
            z = jnp.zeros_like(gx)
            return carry, z, jnp.int32(0), z, jnp.int32(0)

        def b1(carry, mb):
            carry, gx = _b_chunk(carry, mb, 1, _read(carry["g1"], mb))
            z = jnp.zeros_like(gx)
            return carry, gx, jnp.int32(1), z, jnp.int32(0)

        def b1_turn(carry, mb):
            carry, gx = _b_chunk(carry, mb, 1, _read(carry["g1"], mb))
            carry = dict(carry, g0=_write(carry["g0"], mb, gx))
            z = jnp.zeros_like(gx)
            return carry, z, jnp.int32(0), z, jnp.int32(0)

        def b1_loss(carry, mb):
            hctx = _read(carry["hctx"], mb)
            gy, htape, hjoint = M.head_bwd_act(head_p, tp, hctx,
                                               jnp.float32(1.0), cfg)
            carry = dict(carry,
                         htape=_write(carry["htape"], mb, htape),
                         ah=add_partial(carry["ah"], hjoint))
            carry, gx = _b_chunk(carry, mb, 1, gy)
            z = jnp.zeros_like(gx)
            return carry, gx, jnp.int32(1), z, jnp.int32(0)

        # ---- W branches -------------------------------------------------
        def w_nop(carry, mb):
            return carry

        def _w_chunk(carry, mb, which):
            ck = "tape0" if which == 0 else "tape1"
            ak = "a0" if which == 0 else "a1"
            gws = chunk_w(_read(carry[ck], mb))
            acc = carry[ak]
            for i, gw in enumerate(gws):
                acc = add_layer(acc, i, gw)
            carry = dict(carry)
            carry[ak] = acc
            return carry

        def w0(carry, mb):
            return _w_chunk(carry, mb, 0)

        def w1(carry, mb):
            return _w_chunk(carry, mb, 1)

        def w1_head(carry, mb):
            carry = _w_chunk(carry, mb, 1)
            gh = M.head_bwd_weight(_read(carry["htape"], mb))
            return dict(carry, ah=add_partial(carry["ah"], gh))

        # ---- slot body ----------------------------------------------------
        me = jax.lax.axis_index(stage_axis)
        perm_up = [(s, s + 1) for s in range(p - 1)]
        perm_dn = [(s, s - 1) for s in range(1, p)]

        def slot(carry, codes_t):
            my = codes_t[me]
            fmb, bmb_, wmb = my[1], my[3], my[5]
            carry, up_a, up_av, dn_a, dn_av = jax.lax.switch(
                my[0], [f_nop, f0, f0_embed, f0_turn, f1, f1_loss],
                carry, fmb)
            carry, up_g, up_gv, dn_g, dn_gv = jax.lax.switch(
                my[2], [b_nop, b0, b0_embed, b1, b1_turn, b1_loss],
                carry, bmb_)
            carry = jax.lax.switch(
                my[4], [w_nop, w0, w1, w1_head], carry, wmb)
            # exchange.  mb indices are sent +1 so that the zeros a device
            # receives when it has no upstream decode as "invalid" and land
            # in the scratch row m.
            def send(payload, perm):
                return jax.tree.map(
                    lambda x: jax.lax.ppermute(x, stage_axis, perm), payload)

            rx0, rx0_mb, rg1, rg1_mb = send(
                (up_a, jnp.where(up_av > 0, fmb + 1, 0),
                 up_g, jnp.where(up_gv > 0, bmb_ + 1, 0)), perm_up)
            rx1, rx1_mb, rg0, rg0_mb = send(
                (dn_a, jnp.where(dn_av > 0, fmb + 1, 0),
                 dn_g, jnp.where(dn_gv > 0, bmb_ + 1, 0)), perm_dn)
            slot_of = lambda idx: jnp.where(idx > 0, idx - 1, m)
            carry = dict(
                carry,
                x0=_write(carry["x0"], slot_of(rx0_mb), rx0),
                g1=_write(carry["g1"], slot_of(rg1_mb), rg1),
                x1=_write(carry["x1"], slot_of(rx1_mb), rx1),
                g0=_write(carry["g0"], slot_of(rg0_mb), rg0),
            )
            return carry, None

        carry, _ = jax.lax.scan(slot, carry, codes)
        loss = jax.lax.psum(carry["loss"].sum() * scale, stage_axis)
        g0 = jax.tree.map(lambda a: a[None], carry["a0"])
        g1 = jax.tree.map(lambda a: a[None], carry["a1"])
        ge = jax.tree.map(lambda a: jax.lax.psum(a, stage_axis), carry["ae"])
        gh = jax.tree.map(lambda a: jax.lax.psum(a, stage_axis), carry["ah"])
        return loss, g0, g1, ge, gh

    rep = P()
    c_spec = lambda tree: tp_specs(tree, model_axis, stage_axis, lead=2)
    e_spec = lambda tree: tp_specs(tree, None, None)
    h_spec = lambda tree: tp_specs(tree, model_axis, None)
    fn = shard_map(
        run, mesh=mesh,
        in_specs=(c_spec(param_trees[0]), c_spec(param_trees[1]),
                  e_spec(param_trees[2]), h_spec(param_trees[3]), rep, rep),
        out_specs=(rep, c_spec(param_trees[0]), c_spec(param_trees[1]),
                   e_spec(param_trees[2]), h_spec(param_trees[3])),
        check_rep=False,
    )
    return jax.jit(fn)
