"""SPMD pipeline executor: shard_map over a real ``stage`` mesh axis.

One scanned program executes every device's instruction stream in lockstep
slots.  Per slot each device

  1. selects its instruction codes (``lax.switch`` over F/B/W sub-steps;
     a braided F&B block is simply a slot whose F- and B-parts are both
     active — inside one jitted slot their computations are data-independent,
     which is precisely the legal-overlap window the paper engineers),
  2. exchanges boundary tensors with its neighbours via two ``ppermute``s
     whose wiring depends on the placement (``pipeline.slots.WIRING``):

     flat      shift +1 carries activations, shift -1 gradients;
     parallel  both chunks' activations ride +1 and gradients -1 on a
               *wrapped* stage ring (the chunk-0 -> chunk-1 hand-off goes
               from device p-1 back to device 0);
     vshape    shift +1 carries chunk-0 activations and chunk-1 gradients
               (the "V" down-sweep), shift -1 carries chunk-1 activations
               and chunk-0 gradients; turn and loss are device-local.

All six schedule kinds in ``repro.core.schedule.SCHEDULES`` lower through
this one runtime: table -> verified instruction IR -> slot grid -> scanned
shard_map program.  Uniform layer stacks are required
(``n_layers % (v * p) == 0``); TP optionally composes via a ``model`` mesh
axis.  Heterogeneous architectures run through ``pipeline.reference``.

Two entry points share the program body: ``build_pipeline_step`` returns
gradients to the host (differential tests), while
``build_pipeline_train_step`` additionally fuses global-norm clipping and
the AdamW update *under* the same ``shard_map``, so stacked params and
optimizer moments stay mesh-resident across steps (the ``SpmdRunner``
path — no per-step host re-stacking).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.simulator import Placement, flat, parallel, vshape
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim.adamw import OptConfig, adamw_leaf, adamw_scalars
from repro.pipeline import slots as SL
from repro.tp.context import TPContext

_PLACEMENTS = {"flat": flat, "parallel": parallel, "vshape": vshape}


def stages_per_chunk(cfg: ModelConfig, p: int, kind: str = "vshape") -> int:
    """Layers per virtual stage (the placement carries the chunk count)."""
    n_vs = _PLACEMENTS[kind](p).n_vs
    n = cfg.n_layers
    assert n % n_vs == 0, \
        f"SPMD executor needs n_layers % n_vs == 0 (n={n}, n_vs={n_vs})"
    return n // n_vs


def stack_stages(blocks, p: int, lvs: int, kind: str = "vshape"):
    """Per-layer pytree list -> (chunk0, chunk1) stacked with leading
    (p, L_vs) dims.  Stacking is in *device* order per chunk:

      flat      chunk0 vs s = device s; chunk1 empty ({}).
      parallel  chunk0 vs s = device s; chunk1 vs p+s = device s.
      vshape    chunk0 vs s = device s; chunk1 vs 2p-1-s = device s
                (i.e. chunk1 stages stacked in reversed vs order).

    Works on any canonical per-layer list (params, AdamW moments, grads).
    """
    def stack(layers):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)

    def chunk_of(vs_of_dev):
        return stack([stack(blocks[vs_of_dev(s) * lvs:
                                   (vs_of_dev(s) + 1) * lvs])
                      for s in range(p)])

    c0 = chunk_of(lambda s: s)
    if kind == "flat":
        return c0, {}
    if kind == "parallel":
        return c0, chunk_of(lambda s: p + s)
    return c0, chunk_of(lambda s: 2 * p - 1 - s)


def unstack_stages(c0, c1, n_layers: int, p: int, lvs: int,
                   kind: str = "vshape"):
    """Inverse of ``stack_stages``: back to the per-layer pytree list."""
    blocks = [None] * n_layers
    for s in range(p):
        for i in range(lvs):
            blocks[s * lvs + i] = jax.tree.map(lambda x: x[s, i], c0)
            if kind == "flat":
                continue
            vs1 = (p + s) if kind == "parallel" else (2 * p - 1 - s)
            blocks[vs1 * lvs + i] = jax.tree.map(lambda x: x[s, i], c1)
    return blocks


def stack_stage_params(params, cfg: ModelConfig, p: int,
                       kind: str = "vshape"):
    """Canonical params -> (chunk0, chunk1, L_vs); see ``stack_stages``."""
    lvs = stages_per_chunk(cfg, p, kind)
    c0, c1 = stack_stages(params["blocks"], p, lvs, kind)
    return c0, c1, lvs


def unstack_stage_grads(g0, g1, cfg: ModelConfig, p: int, lvs: int,
                        kind: str = "vshape"):
    """Inverse of ``stack_stage_params`` for the gradient pytrees."""
    return unstack_stages(g0, g1, cfg.n_layers, p, lvs, kind)


def _zeros_like_tree(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), tree)


# ---------------------------------------------------------------------------
# Megatron-style TP sharding rules for the unit-mode (shard_map) params.
# Column-parallel: qkv / up projections split their output dim; row-parallel:
# down/out projections split their input dim; norm gains, routers and small
# core params are replicated; the LM head is vocab-parallel.
# ---------------------------------------------------------------------------

def _tp_axis_of(name: str, base_ndim: int):
    """TP shard axis (negative, counted from the right) for a named param,
    or None if replicated.  Column-parallel projections split their output
    dim, row-parallel split their input dim, heads axes shard for the
    head-blocked mLSTM mixers; routers / norms / small cores replicate.
    sLSTM in-projections interleave four gate blocks and stay replicated
    (DESIGN.md §Arch-applicability)."""
    col2 = {"wg", "wu", "w1", "w_in_x", "w_in_z", "w_upx", "w_upz", "w_lm"}
    row2 = {"wo", "wd", "w2", "w_out", "w_down"}
    if name in ("wq", "wk", "wv"):
        return -3 if base_ndim >= 3 else -1              # mlstm heads / attn
    if name in ("wi", "wf"):
        return -2                                        # mlstm gate heads
    if name in col2:
        return -1
    if name in row2:
        return -2
    return None


def tp_specs(tree, model_axis: Optional[str], stage_axis: Optional[str],
             lead: int = 0):
    """PartitionSpec tree for a params pytree.  ``lead`` extra leading dims
    (stage stack + per-vs layer stack) precede the parameter's own dims; if
    ``stage_axis`` is given it names the first of them."""
    def one(path, leaf):
        name = None
        for k in reversed(path):
            if hasattr(k, "key"):
                name = k.key
                break
        spec = [None] * leaf.ndim
        if stage_axis is not None:
            spec[0] = stage_axis
        ax = _tp_axis_of(name, leaf.ndim - lead) if model_axis else None
        if ax is not None:
            spec[leaf.ndim + ax] = model_axis
        return P(*spec)
    return jax.tree_util.tree_map_with_path(one, tree)


def _read(buf, mb):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, mb, 0, keepdims=False), buf)


def _write(buf, mb, val):
    return jax.tree.map(
        lambda a, v: jax.lax.dynamic_update_index_in_dim(
            a, v.astype(a.dtype), mb, 0), buf, val)


def _local_sds(tree, tp_size: int, lead: int, strip: int):
    """ShapeDtypeStructs of the per-device shards: drop ``strip`` leading
    (stage) dims and divide TP-ruled axes by ``tp_size``."""
    def one(path, leaf):
        name = None
        for k in reversed(path):
            if hasattr(k, "key"):
                name = k.key
                break
        shape = list(leaf.shape[strip:])
        ax = _tp_axis_of(name, leaf.ndim - lead)
        if ax is not None and tp_size > 1:
            shape[ax] = shape[ax] // tp_size
        return jax.ShapeDtypeStruct(tuple(shape), leaf.dtype)
    return jax.tree_util.tree_map_with_path(one, tree)


def _pipeline_program(cfg: ModelConfig, tables, pl: Placement, mesh: Mesh,
                      m: int, mb_shape, param_trees, *,
                      stage_axis: str = "stage",
                      model_axis: Optional[str] = None,
                      fuse: bool = True, ablate: Optional[str] = None,
                      braid_tp: bool = False):
    """Build the per-device slot program ``run(c0, c1, embed_p, head_p,
    tokens, labels) -> (loss, g0, g1, g_embed, g_head)`` to be wrapped in
    ``shard_map`` — shared by the grads-only step and the fused train step.

    ``fuse`` selects the lowering of the static slot grid:

      False — generic: one scan over all slots, three ``lax.switch``
              dispatches per slot (F/B/W), every wired boundary stream
              exchanged every slot as a (payload, mb-flag) ppermute pair.
      True  — fused (default): the grid is partitioned into maximal
              constant-role *segments* (``slots.segment_grid``).  Each
              segment lowers as its own scan whose body composes the three
              branch bodies at trace time (role codes are Python ints per
              segment), leaving at most ONE ``lax.switch`` per slot — over
              the segment's distinct per-device role rows, and none at all
              when the row is uniform — and exchanges only the segment's
              statically-live streams as bare payloads (receive rows are
              read from the static grid, so the flag channel disappears).

    Both lowerings share the same branch bodies, so they are numerically
    identical up to float reassociation (pinned by the differential tests).

    ``ablate`` builds benchmark-only variants for the ``--breakdown`` cost
    split (numerics are meaningless): ``"exchange"`` elides every ppermute;
    ``"compute"`` replaces branch bodies with buffer-touching stubs that
    keep the dispatch + exchange structure (and a loss data-dependence so
    XLA cannot dead-code it); ``"both"`` applies both; ``"tp"`` executes
    the full model math with an identity TPContext (no model-axis
    collectives; shard shapes keep the real TP size), isolating the
    TP-collective share of the wall clock.

    ``braid_tp`` lowers composite F&B slots through the braided chunk
    executor (``model.chunk_fwd_bwd_braided``): unit-interleaved partner
    chunks with ring-decomposed output collectives, instead of the
    sequential chunk_f-then-chunk_b composition.
    """
    assert ablate in (None, "exchange", "compute", "both", "tp")
    do_exchange = ablate not in ("exchange", "both")
    braid = braid_tp and ablate not in ("compute", "both")
    p = pl.p
    two_chunks = pl.kind != "flat"
    grid = SL.to_slots(tables, pl)
    codes_np = SL.encode(grid, pl)                      # (L, p, 6) static
    wiring = SL.WIRING[pl.kind]
    act_streams = tuple(s for s in ("x0", "x1")
                        if s in wiring["up"] + wiring["dn"])
    grad_streams = tuple(s for s in ("g0", "g1")
                         if s in wiring["up"] + wiring["dn"])
    # safe_ring: braided ring hops run inside lax.switch arms that only
    # some stage rows take; ppermute would deadlock there (XLA:CPU
    # rendezvouses collective-permute globally), so hops lower as per-group
    # one-hot psums instead.
    tp = TPContext(axis=model_axis,
                   size=(mesh.shape[model_axis] if model_axis else 1),
                   safe_ring=True)
    # ablate="tp": execute with an identity context (no model-axis
    # collectives) while `tp` keeps the real size for shard shapes.
    tp_exec = TPContext() if ablate == "tp" else tp
    lvs = stages_per_chunk(cfg, p, pl.kind)
    specs0 = cfg.layers[:lvs]                           # uniform stacks
    bmb, seq = mb_shape
    d_model = cfg.d_model
    scale = 1.0 / m
    rope = M._rope_for(cfg, seq)

    def chunk_f(cparams, x, tpc=tp_exec):
        layers = [jax.tree.map(lambda a: a[i], cparams)
                  for i in range(lvs)]
        return M.chunk_fwd(layers, tpc, x, rope, specs0, cfg)

    def chunk_b(cparams, ctxs, gy, tpc=tp_exec):
        layers = [jax.tree.map(lambda a: a[i], cparams)
                  for i in range(lvs)]
        return M.chunk_bwd_act(layers, tpc, ctxs, gy, specs0, cfg)

    def chunk_fb(f_cparams, x, b_cparams, ctxs, gy):
        f_layers = [jax.tree.map(lambda a: a[i], f_cparams)
                    for i in range(lvs)]
        b_layers = [jax.tree.map(lambda a: a[i], b_cparams)
                    for i in range(lvs)]
        return M.chunk_fwd_bwd_braided(f_layers, x, b_layers, ctxs, gy,
                                       tp_exec, rope, specs0, cfg)

    def chunk_w(tapes):
        return M.chunk_bwd_weight(tapes, specs0)

    # --- trace shapes for context/tape buffers --------------------------
    x_sds = jax.ShapeDtypeStruct((bmb, seq, d_model), jnp.float32)
    lab_sds = jax.ShapeDtypeStruct((bmb, seq), jnp.int32)

    # Buffer shapes are traced with an identity TPContext over the *local*
    # shard shapes — collectives preserve shapes, so the unit-mode buffers
    # match (eval_shape cannot bind mesh axis names).
    tp0 = TPContext()
    cp_sds = _local_sds(param_trees[0], tp.size, lead=2, strip=1)
    _, ctx_sds = jax.eval_shape(lambda c, x: chunk_f(c, x, tp0),
                                cp_sds, x_sds)
    gx_sds, tape_sds, joint_sds = jax.eval_shape(
        lambda c, cx, g: chunk_b(c, cx, g, tp0), cp_sds, ctx_sds, x_sds)
    head_sds = _local_sds(param_trees[3], tp.size, lead=0, strip=0)
    _, hctx_sds = jax.eval_shape(
        lambda hp, x, lab: M.head_fwd(hp, tp0, x, lab, cfg),
        head_sds, x_sds, lab_sds)
    _, htape_sds, hjoint_sds = jax.eval_shape(
        lambda hp, c: M.head_bwd_act(hp, tp0, c, jnp.float32(1.0), cfg),
        head_sds, hctx_sds)

    def zeros_of(sds_tree, lead=None):
        return jax.tree.map(
            lambda s: jnp.zeros(((lead,) + s.shape) if lead else s.shape,
                                s.dtype), sds_tree)

    def run(c0, c1, embed_p, head_p, tokens, labels):
        """Per-device body (inside shard_map).  c0/c1 carry a leading
        stage dim of 1 (c1 is the empty pytree for flat placements)."""
        c0 = jax.tree.map(lambda a: a[0], c0)
        c1 = jax.tree.map(lambda a: a[0], c1)
        zrow = lambda: jnp.zeros((m + 1, bmb, seq, d_model), jnp.float32)
        carry = {
            "x0": zrow(), "g0": zrow(),
            "ctx0": zeros_of(ctx_sds, m), "tape0": zeros_of(tape_sds, m),
            "hctx": zeros_of(hctx_sds, m), "htape": zeros_of(htape_sds, m),
            "loss": jnp.zeros((m,), jnp.float32),
            "a0": _zeros_like_tree(c0),
            "ae": _zeros_like_tree(embed_p),
            "ah": _zeros_like_tree(head_p),
        }
        if two_chunks:
            carry.update({
                "x1": zrow(), "g1": zrow(),
                "ctx1": zeros_of(ctx_sds, m), "tape1": zeros_of(tape_sds, m),
                "a1": _zeros_like_tree(c1),
            })

        def add_partial(acc, new, s=scale):
            if isinstance(new, dict):
                out = dict(acc)
                for k, v in new.items():
                    out[k] = add_partial(acc[k], v, s)
                return out
            return jax.tree.map(lambda a, b: a + s * b.astype(a.dtype),
                                acc, new)

        def add_layer(acc, i, new, s=scale):
            """acc leaves have leading layer dim; new is one layer's partial
            grad dict."""
            if isinstance(new, dict):
                out = dict(acc)
                for k, v in new.items():
                    out[k] = add_layer(acc[k], i, v, s)
                return out
            return acc.at[i].add(s * new.astype(acc.dtype))

        zx = lambda: jnp.zeros((bmb, seq, d_model), jnp.float32)

        def acts_out(**valid):
            """Per-act-stream (payload, flag) tuple, invalid by default."""
            return tuple(valid.get(s, (zx(), jnp.int32(0)))
                         for s in act_streams)

        def grads_out(**valid):
            return tuple(valid.get(s, (zx(), jnp.int32(0)))
                         for s in grad_streams)

        def _head_f(carry, mb, y):
            loss, hctx = M.head_fwd(head_p, tp_exec, y, _read(labels, mb),
                                    cfg)
            return dict(carry,
                        hctx=_write(carry["hctx"], mb, hctx),
                        loss=carry["loss"].at[mb].set(loss))

        def _head_b(carry, mb):
            gy, htape, hjoint = M.head_bwd_act(
                head_p, tp_exec, _read(carry["hctx"], mb), jnp.float32(1.0),
                cfg)
            carry = dict(carry,
                         htape=_write(carry["htape"], mb, htape),
                         ah=add_partial(carry["ah"], hjoint))
            return carry, gy

        # ---- F branches -------------------------------------------------
        def f_nop(carry, mb):
            return carry, acts_out()

        def _f_chunk(carry, mb, which, src):
            cp, ck = (c0, "ctx0") if which == 0 else (c1, "ctx1")
            y, ctxs = chunk_f(cp, src)
            carry = dict(carry, **{ck: _write(carry[ck], mb, ctxs)})
            return carry, y

        def f0(carry, mb):
            carry, y = _f_chunk(carry, mb, 0, _read(carry["x0"], mb))
            return carry, acts_out(x0=(y, jnp.int32(1)))

        def f0_embed(carry, mb):
            batch = ({"tokens": _read(tokens, mb)} if cfg.frontend == "text"
                     else {"embeds": _read(tokens, mb)})
            x, _ = M.embed_fwd(embed_p, batch, cfg)
            carry, y = _f_chunk(carry, mb, 0, x)
            return carry, acts_out(x0=(y, jnp.int32(1)))

        def f0_turn(carry, mb):
            """vshape: chunk-0 output enters chunk 1 on the same device."""
            carry, y = _f_chunk(carry, mb, 0, _read(carry["x0"], mb))
            carry = dict(carry, x1=_write(carry["x1"], mb, y))
            return carry, acts_out()

        def f0_send1(carry, mb):
            """parallel: chunk-0 output wraps to device 0's chunk 1."""
            carry, y = _f_chunk(carry, mb, 0, _read(carry["x0"], mb))
            return carry, acts_out(x1=(y, jnp.int32(1)))

        def f0_loss(carry, mb):
            """flat: last stage forward + loss head, no output."""
            carry, y = _f_chunk(carry, mb, 0, _read(carry["x0"], mb))
            return _head_f(carry, mb, y), acts_out()

        def f1(carry, mb):
            carry, y = _f_chunk(carry, mb, 1, _read(carry["x1"], mb))
            return carry, acts_out(x1=(y, jnp.int32(1)))

        def f1_loss(carry, mb):
            carry, y = _f_chunk(carry, mb, 1, _read(carry["x1"], mb))
            return _head_f(carry, mb, y), acts_out()

        # ---- B branches -------------------------------------------------
        def b_nop(carry, mb):
            return carry, grads_out()

        def _b_chunk(carry, mb, which, gy):
            cp = c0 if which == 0 else c1
            ctxs = _read(carry["ctx0" if which == 0 else "ctx1"], mb)
            gx, tapes, joints = chunk_b(cp, ctxs, gy)
            ck = "tape0" if which == 0 else "tape1"
            ak = "a0" if which == 0 else "a1"
            carry = dict(carry)
            carry[ck] = _write(carry[ck], mb, tapes)
            acc = carry[ak]
            for i, j in enumerate(joints):
                acc = add_layer(acc, i, j)
            carry[ak] = acc
            return carry, gx

        def b0(carry, mb):
            carry, gx = _b_chunk(carry, mb, 0, _read(carry["g0"], mb))
            return carry, grads_out(g0=(gx, jnp.int32(1)))

        def b0_embed(carry, mb):
            carry, gx = _b_chunk(carry, mb, 0, _read(carry["g0"], mb))
            batch = ({"tokens": _read(tokens, mb)} if cfg.frontend == "text"
                     else {"embeds": _read(tokens, mb)})
            _, ectx = M.embed_fwd(embed_p, batch, cfg)
            ge = M.embed_bwd_weight(embed_p, ectx, gx)
            carry = dict(carry, ae=add_partial(carry["ae"], ge))
            return carry, grads_out()

        def b0_loss(carry, mb):
            """flat: loss head backward + last stage backward."""
            carry, gy = _head_b(carry, mb)
            carry, gx = _b_chunk(carry, mb, 0, gy)
            return carry, grads_out(g0=(gx, jnp.int32(1)))

        def b1(carry, mb):
            carry, gx = _b_chunk(carry, mb, 1, _read(carry["g1"], mb))
            return carry, grads_out(g1=(gx, jnp.int32(1)))

        def b1_turn(carry, mb):
            """vshape: chunk-1 gradient enters chunk 0 on the same device."""
            carry, gx = _b_chunk(carry, mb, 1, _read(carry["g1"], mb))
            carry = dict(carry, g0=_write(carry["g0"], mb, gx))
            return carry, grads_out()

        def b1_send0(carry, mb):
            """parallel: chunk-1 gradient wraps to device p-1's chunk 0."""
            carry, gx = _b_chunk(carry, mb, 1, _read(carry["g1"], mb))
            return carry, grads_out(g0=(gx, jnp.int32(1)))

        def b1_loss(carry, mb):
            carry, gy = _head_b(carry, mb)
            carry, gx = _b_chunk(carry, mb, 1, gy)
            return carry, grads_out(g1=(gx, jnp.int32(1)))

        # ---- W branches -------------------------------------------------
        def w_nop(carry, mb):
            return carry

        def _w_chunk(carry, mb, which):
            ck = "tape0" if which == 0 else "tape1"
            ak = "a0" if which == 0 else "a1"
            gws = chunk_w(_read(carry[ck], mb))
            acc = carry[ak]
            for i, gw in enumerate(gws):
                acc = add_layer(acc, i, gw)
            carry = dict(carry)
            carry[ak] = acc
            return carry

        def _w_head(carry, mb):
            gh = M.head_bwd_weight(_read(carry["htape"], mb))
            return dict(carry, ah=add_partial(carry["ah"], gh))

        def w0(carry, mb):
            return _w_chunk(carry, mb, 0)

        def w0_head(carry, mb):
            return _w_head(_w_chunk(carry, mb, 0), mb)

        def w1(carry, mb):
            return _w_chunk(carry, mb, 1)

        def w1_head(carry, mb):
            return _w_head(_w_chunk(carry, mb, 1), mb)

        fdefs = dict(f_nop=f_nop, f0=f0, f0_embed=f0_embed, f0_turn=f0_turn,
                     f0_send1=f0_send1, f0_loss=f0_loss, f1=f1,
                     f1_loss=f1_loss)
        bdefs = dict(b_nop=b_nop, b0=b0, b0_embed=b0_embed, b0_loss=b0_loss,
                     b1=b1, b1_turn=b1_turn, b1_send0=b1_send0,
                     b1_loss=b1_loss)
        wdefs = dict(w_nop=w_nop, w0=w0, w0_head=w0_head, w1=w1,
                     w1_head=w1_head)

        if ablate in ("compute", "both"):
            # --breakdown stubs: per-role buffer touch + emit, preserving
            # the dispatch arms, stream liveness and a loss data-dependence
            # (every exchange chain terminates in `loss`, so XLA keeps the
            # switch + ppermute skeleton) while dropping the model math.
            def _touch(out, src, emit=None, store=None, to_loss=False):
                def fn(carry, mb):
                    val = _read(carry[src], mb)
                    if store:
                        carry = dict(carry,
                                     **{store: _write(carry[store], mb,
                                                      val)})
                    if to_loss:
                        carry = dict(carry, loss=carry["loss"].at[mb].add(
                            jnp.sum(val)))
                    if emit is None:
                        return (carry, out()) if out else carry
                    return carry, out(**{emit: (val, jnp.int32(1))})
                return fn

            fdefs = dict(
                f_nop=f_nop,
                f0=_touch(acts_out, "x0", emit="x0"),
                f0_embed=_touch(acts_out, "x0", emit="x0"),
                f0_turn=_touch(acts_out, "x0", store="x1"),
                f0_send1=_touch(acts_out, "x0", emit="x1"),
                f0_loss=_touch(acts_out, "x0", to_loss=True),
                f1=_touch(acts_out, "x1", emit="x1"),
                f1_loss=_touch(acts_out, "x1", to_loss=True))
            bdefs = dict(
                b_nop=b_nop,
                b0=_touch(grads_out, "g0", emit="g0"),
                b0_embed=_touch(grads_out, "g0", to_loss=True),
                b0_loss=_touch(grads_out, "g0", emit="g0"),
                b1=_touch(grads_out, "g1", emit="g1"),
                b1_turn=_touch(grads_out, "g1", store="g0"),
                b1_send0=_touch(grads_out, "g1", emit="g0"),
                b1_loss=_touch(grads_out, "g1", emit="g1"))
            wdefs = {k: w_nop for k in wdefs}

        f_branches = [fdefs[n] for n in SL.F_BRANCHES[pl.kind]]
        b_branches = [bdefs[n] for n in SL.B_BRANCHES[pl.kind]]
        w_branches = [wdefs[n] for n in SL.W_BRANCHES[pl.kind]]

        # ---- braided composite F&B arms (paper §4, Fig. 1) --------------
        # A composite slot (both F and B active) lowers as ONE braided
        # chunk call instead of chunk_f-then-chunk_b, so each side's TP ring
        # hops interleave with the partner's matmuls.  The per-branch
        # source/emit plumbing is factored out of the f*/b* branch bodies
        # above so the braided arm reproduces them exactly.
        F_SRC = {"f0": "x0", "f0_embed": None, "f0_turn": "x0",
                 "f0_send1": "x0", "f0_loss": "x0", "f1": "x1",
                 "f1_loss": "x1"}
        F_CHUNK = {"f0": 0, "f0_embed": 0, "f0_turn": 0, "f0_send1": 0,
                   "f0_loss": 0, "f1": 1, "f1_loss": 1}
        B_CHUNK = {"b0": 0, "b0_embed": 0, "b0_loss": 0, "b1": 1,
                   "b1_turn": 1, "b1_send0": 1, "b1_loss": 1}

        def _embed_x(mb):
            batch = ({"tokens": _read(tokens, mb)} if cfg.frontend == "text"
                     else {"embeds": _read(tokens, mb)})
            x, _ = M.embed_fwd(embed_p, batch, cfg)
            return x

        def _f_emit(name, carry, mb, y):
            if name in ("f0", "f0_embed"):
                return carry, acts_out(x0=(y, jnp.int32(1)))
            if name in ("f0_send1", "f1"):
                return carry, acts_out(x1=(y, jnp.int32(1)))
            if name == "f0_turn":
                return dict(carry, x1=_write(carry["x1"], mb, y)), acts_out()
            return _head_f(carry, mb, y), acts_out()    # f0_loss / f1_loss

        def _b_gy(name, carry, mb):
            if name in ("b0_loss", "b1_loss"):
                return _head_b(carry, mb)
            return carry, _read(carry["g0" if B_CHUNK[name] == 0 else "g1"],
                                mb)

        def _b_emit(name, carry, mb, gx):
            if name == "b0_embed":
                batch = ({"tokens": _read(tokens, mb)}
                         if cfg.frontend == "text"
                         else {"embeds": _read(tokens, mb)})
                _, ectx = M.embed_fwd(embed_p, batch, cfg)
                ge = M.embed_bwd_weight(embed_p, ectx, gx)
                return (dict(carry, ae=add_partial(carry["ae"], ge)),
                        grads_out())
            if name in ("b0", "b0_loss", "b1_send0"):
                return carry, grads_out(g0=(gx, jnp.int32(1)))
            if name == "b1_turn":
                return (dict(carry, g0=_write(carry["g0"], mb, gx)),
                        grads_out())
            return carry, grads_out(g1=(gx, jnp.int32(1)))   # b1 / b1_loss

        def braided_fb(fname, bname):
            fck, bck = F_CHUNK[fname], B_CHUNK[bname]
            fcp = c0 if fck == 0 else c1
            bcp = c0 if bck == 0 else c1
            fctx_key = "ctx0" if fck == 0 else "ctx1"
            bctx_key = "ctx0" if bck == 0 else "ctx1"
            tape_key = "tape0" if bck == 0 else "tape1"
            ak = "a0" if bck == 0 else "a1"
            src = F_SRC[fname]

            def fb(carry, fmb, bmb_):
                x = _embed_x(fmb) if src is None else _read(carry[src], fmb)
                ctxs_in = _read(carry[bctx_key], bmb_)
                carry, gy = _b_gy(bname, carry, bmb_)
                y, ctxs, gx, tapes, joints = chunk_fb(fcp, x, bcp, ctxs_in,
                                                      gy)
                carry = dict(carry, **{
                    fctx_key: _write(carry[fctx_key], fmb, ctxs)})
                carry[tape_key] = _write(carry[tape_key], bmb_, tapes)
                acc = carry[ak]
                for i, j in enumerate(joints):
                    acc = add_layer(acc, i, j)
                carry[ak] = acc
                carry, acts = _f_emit(fname, carry, fmb, y)
                carry, grads = _b_emit(bname, carry, bmb_, gx)
                return carry, acts, grads
            return fb

        # ---- slot body --------------------------------------------------
        me = jax.lax.axis_index(stage_axis)
        if wiring["wrap"]:
            perm_up = [(s, (s + 1) % p) for s in range(p)]
            perm_dn = [(s, (s - 1) % p) for s in range(p)]
        else:
            perm_up = [(s, s + 1) for s in range(p - 1)]
            perm_dn = [(s, s - 1) for s in range(1, p)]
        perm_of = {"up": perm_up, "dn": perm_dn}

        def _exchange(carry, acts, grads, fmb, bmb_):
            # exchange.  mb indices are sent +1 so that the zeros a device
            # receives when it has no upstream decode as "invalid" and land
            # in the scratch row m.
            stream = {}
            for s, (val, ok) in zip(act_streams, acts):
                stream[s] = (val, jnp.where(ok > 0, fmb + 1, 0))
            for s, (val, ok) in zip(grad_streams, grads):
                stream[s] = (val, jnp.where(ok > 0, bmb_ + 1, 0))

            def send(payload, perm):
                return jax.tree.map(
                    lambda x: jax.lax.ppermute(x, stage_axis, perm), payload)

            for names, perm in ((wiring["up"], perm_up),
                                (wiring["dn"], perm_dn)):
                rx = send(tuple(stream[s] for s in names), perm)
                for s, (val, mbidx) in zip(names, rx):
                    row = jnp.where(mbidx > 0, mbidx - 1, m)
                    carry = dict(carry,
                                 **{s: _write(carry[s], row, val)})
            return carry

        def generic_slot(carry, codes_t):
            my = codes_t[me]
            fmb, bmb_, wmb = my[1], my[3], my[5]
            carry, acts = jax.lax.switch(my[0], f_branches, carry, fmb)
            carry, grads = jax.lax.switch(my[2], b_branches, carry, bmb_)
            carry = jax.lax.switch(my[4], w_branches, carry, wmb)
            if not do_exchange:
                return carry, None
            return _exchange(carry, acts, grads, fmb, bmb_), None

        def generic_braid_slot(carry, xs_t):
            """Generic lowering under braid_tp: F and B dispatch through one
            joint switch over the grid's distinct static (F, B) role pairs
            so composite pairs can lower as a single braided call."""
            codes_t, pc_t = xs_t
            my = codes_t[me]
            fmb, bmb_, wmb = my[1], my[3], my[5]
            carry, acts, grads = jax.lax.switch(pc_t[me], pair_arms, carry,
                                                fmb, bmb_)
            carry = jax.lax.switch(my[4], w_branches, carry, wmb)
            if not do_exchange:
                return carry, None
            return _exchange(carry, acts, grads, fmb, bmb_), None

        if braid and not fuse:
            fb_names = SL.F_BRANCHES[pl.kind]
            bb_names = SL.B_BRANCHES[pl.kind]
            pairs = sorted({(int(c[0]), int(c[2]))
                            for c in codes_np.reshape(-1, 6)})
            pair_codes = np.array(
                [[pairs.index((int(codes_np[t, d, 0]),
                               int(codes_np[t, d, 2])))
                  for d in range(p)]
                 for t in range(codes_np.shape[0])], np.int32)

            def pair_arm(fc, bc):
                if fc > 0 and bc > 0:
                    return braided_fb(fb_names[fc], bb_names[bc])

                def seq(carry, fmb, bmb_):
                    carry, acts = f_branches[fc](carry, fmb)
                    carry, grads = b_branches[bc](carry, bmb_)
                    return carry, acts, grads
                return seq

            pair_arms = [pair_arm(fc, bc) for fc, bc in pairs]

        def run_segment(carry, seg):
            """Fused lowering of one periodic segment: branch bodies
            composed at trace time from each phase's static role rows, one
            scan over its iterations (mb indices + static receive rows are
            the only scanned values), dead streams elided per phase from
            the exchange.  The scan body unrolls the segment's ``period``
            phases, so steady-state braids (F,BW,F,BW,... in 1f1b and the
            zero-bubble family) trace one loop body instead of one inlined
            program per slot."""
            k = seg.period

            def arm_of(fc, bc, wc):
                wf = w_branches[wc]
                if braid and fc > 0 and bc > 0:
                    fb = braided_fb(SL.F_BRANCHES[pl.kind][fc],
                                    SL.B_BRANCHES[pl.kind][bc])

                    def braided_arm(carry, mb3):
                        carry, acts, grads = fb(carry, mb3[0], mb3[1])
                        carry = wf(carry, mb3[2])
                        return (carry, tuple(v for v, _ in acts),
                                tuple(v for v, _ in grads))
                    return braided_arm
                ff = f_branches[fc]
                bf = b_branches[bc]

                def arm(carry, mb3):
                    carry, acts = ff(carry, mb3[0])
                    carry, grads = bf(carry, mb3[1])
                    carry = wf(carry, mb3[2])
                    return (carry, tuple(v for v, _ in acts),
                            tuple(v for v, _ in grads))
                return arm

            arms, row_id = [], []
            for ph in seg.phases:
                distinct = list(dict.fromkeys(ph))
                arms.append([arm_of(*r) for r in distinct])
                row_id.append(jnp.asarray(
                    np.array([distinct.index(r) for r in ph], np.int32)))

            def one_phase(carry, j, mb_t, rr_t):
                # mb_t: (p, 3), rr_t: (p, n_live of phase j)
                my = mb_t[me]
                if len(arms[j]) == 1:
                    carry, acts, grads = arms[j][0](carry, my)
                else:
                    carry, acts, grads = jax.lax.switch(
                        row_id[j][me], arms[j], carry, my)
                if not do_exchange:
                    return carry
                vals = dict(zip(act_streams, acts))
                vals.update(zip(grad_streams, grads))
                i = 0
                for names, perm in ((seg.live[j][0], perm_of["up"]),
                                    (seg.live[j][1], perm_of["dn"])):
                    for s in names:
                        rx = jax.lax.ppermute(vals[s], stage_axis, perm)
                        carry = dict(carry, **{s: _write(carry[s],
                                                         rr_t[me, i], rx)})
                        i += 1
                return carry

            mbs = codes_np[seg.start:seg.stop, :, 1::2]
            rr = SL.recv_rows(codes_np, seg, pl.kind, m)
            if seg.n_iters == 1:
                for j in range(k):
                    carry = one_phase(carry, j, jnp.asarray(mbs[j]),
                                      jnp.asarray(rr[j][0]))
                return carry

            def seg_body(carry, xs):
                for j in range(k):
                    carry = one_phase(carry, j, xs[j], xs[k + j])
                return carry, None

            xs = (tuple(jnp.asarray(mbs[j::k]) for j in range(k))
                  + tuple(jnp.asarray(r) for r in rr))
            carry, _ = jax.lax.scan(seg_body, carry, xs)
            return carry

        if fuse:
            for seg in SL.segment_grid(codes_np, pl.kind):
                carry = run_segment(carry, seg)
        elif braid:
            carry, _ = jax.lax.scan(generic_braid_slot, carry,
                                    (jnp.asarray(codes_np),
                                     jnp.asarray(pair_codes)))
        else:
            carry, _ = jax.lax.scan(generic_slot, carry,
                                    jnp.asarray(codes_np))
        loss = jax.lax.psum(carry["loss"].sum() * scale, stage_axis)
        g0 = jax.tree.map(lambda a: a[None], carry["a0"])
        g1 = (jax.tree.map(lambda a: a[None], carry["a1"])
              if two_chunks else {})
        ge = jax.tree.map(lambda a: jax.lax.psum(a, stage_axis), carry["ae"])
        gh = jax.tree.map(lambda a: jax.lax.psum(a, stage_axis), carry["ah"])
        return loss, g0, g1, ge, gh

    return run


def stage_param_specs(param_trees, *, stage_axis: str = "stage",
                      model_axis: Optional[str] = None) -> dict:
    """PartitionSpec dict for the stage-layout state params
    ``{"c0", "c1", "embed", "head"}`` given (c0, c1, embed, head) trees."""
    return {"c0": tp_specs(param_trees[0], model_axis, stage_axis, lead=2),
            "c1": tp_specs(param_trees[1], model_axis, stage_axis, lead=2),
            "embed": tp_specs(param_trees[2], None, None),
            "head": tp_specs(param_trees[3], model_axis, None)}


def build_pipeline_step(cfg: ModelConfig, tables, pl: Placement, mesh: Mesh,
                        m: int, mb_shape, param_trees, *,
                        stage_axis: str = "stage",
                        model_axis: Optional[str] = None,
                        fuse_slots: bool = True,
                        ablate: Optional[str] = None,
                        braid_tp: bool = False):
    """Returns a jitted SPMD function
    ``step(c0, c1, embed_p, head_p, tokens, labels) -> (loss, g0, g1,
    g_embed, g_head)`` executing the schedule over the ``stage`` (and
    optionally ``model``) mesh axes, for any placement kind
    (flat / parallel / vshape).

    mb_shape: (mb_batch, seq) of one microbatch.
    param_trees: (c0, c1, embed_p, head_p) — global (unsharded) pytrees or
    ShapeDtypeStructs; used to derive shard specs and local buffer shapes.
    For flat placements c1 is the empty pytree ``{}``.

    ``fuse_slots`` selects the fused segment lowering (default) vs the
    generic one-switch-per-slot scan; ``ablate`` builds the benchmark-only
    cost-breakdown variants; ``braid_tp`` routes composite F&B slots
    through the braided overlap-aware chunk executor (see
    ``_pipeline_program``).
    """
    run = _pipeline_program(cfg, tables, pl, mesh, m, mb_shape, param_trees,
                            stage_axis=stage_axis, model_axis=model_axis,
                            fuse=fuse_slots, ablate=ablate, braid_tp=braid_tp)
    rep = P()
    sp = stage_param_specs(param_trees, stage_axis=stage_axis,
                           model_axis=model_axis)
    fn = shard_map(
        run, mesh=mesh,
        in_specs=(sp["c0"], sp["c1"], sp["embed"], sp["head"], rep, rep),
        out_specs=(rep, sp["c0"], sp["c1"], sp["embed"], sp["head"]),
        check_rep=False,
    )
    return jax.jit(fn)


def _dup_factors(param_trees, mesh: Mesh, *, stage_axis: str,
                 model_axis: Optional[str]) -> dict:
    """Per-leaf replica counts of the *gradients* across the (stage, model)
    mesh axes, keyed like the state params dict.  Block grads are unique per
    stage and TP-sharded where the param is; embed/head grads come out of
    the program psum'd over ``stage`` so every stage row holds a full copy.
    Used to weight local sum-of-squares so the global grad norm counts every
    element exactly once."""
    p = mesh.shape[stage_axis]
    tp_size = mesh.shape[model_axis] if model_axis else 1

    def group(tree, lead, base):
        def one(path, leaf):
            name = None
            for k in reversed(path):
                if hasattr(k, "key"):
                    name = k.key
                    break
            ax = (_tp_axis_of(name, leaf.ndim - lead)
                  if model_axis else None)
            return base * (1 if ax is not None else tp_size)
        return jax.tree_util.tree_map_with_path(one, tree)

    return {"c0": group(param_trees[0], 2, 1),
            "c1": group(param_trees[1], 2, 1),
            "embed": jax.tree.map(lambda _: p * tp_size, param_trees[2]),
            "head": group(param_trees[3], 0, p)}


def build_pipeline_train_step(cfg: ModelConfig, tables, pl: Placement,
                              mesh: Mesh, m: int, mb_shape, param_trees,
                              oc: OptConfig, *,
                              stage_axis: str = "stage",
                              model_axis: Optional[str] = None,
                              fuse_slots: bool = True,
                              braid_tp: bool = False):
    """Fused pipeline *train* step: schedule execution, global-norm
    clipping and the AdamW update all under one ``shard_map`` — stacked
    params and optimizer moments never leave the mesh between steps.

    Returns a jitted ``train(params, opt, tokens, labels) -> (params', opt',
    loss, gnorm)`` where ``params`` is the stage-layout dict
    ``{"c0", "c1", "embed", "head"}`` (c1 = {} for flat placements) and
    ``opt = {"mu": like params, "nu": like params, "step": int32[]}``.

    The global grad norm is assembled from per-device partial sums weighted
    by each leaf's replica count (`_dup_factors`), then psum'd over the
    stage (and model) axes, so clipping matches the host
    ``optim.adamw_update`` on canonical grads up to float reassociation.
    Weight decay applies to leaves whose *canonical* rank is >= 2 (the two
    stacking dims of c0/c1 don't count).
    """
    run = _pipeline_program(cfg, tables, pl, mesh, m, mb_shape, param_trees,
                            stage_axis=stage_axis, model_axis=model_axis,
                            fuse=fuse_slots, braid_tp=braid_tp)
    sp = stage_param_specs(param_trees, stage_axis=stage_axis,
                           model_axis=model_axis)
    ospec = {"mu": sp, "nu": sp, "step": P()}
    dup = _dup_factors(param_trees, mesh, stage_axis=stage_axis,
                       model_axis=model_axis)
    lead = {"c0": 2, "c1": 2, "embed": 0, "head": 0}
    axes = ((stage_axis, model_axis) if model_axis else (stage_axis,))

    def train(params, opt, tokens, labels):
        loss, g0, g1, ge, gh = run(params["c0"], params["c1"],
                                   params["embed"], params["head"],
                                   tokens, labels)
        grads = {"c0": g0, "c1": g1, "embed": ge, "head": gh}
        sq = sum((jnp.sum(jnp.square(g.astype(jnp.float32))) / d
                  for g, d in zip(jax.tree.leaves(grads),
                                  jax.tree.leaves(dup))),
                 start=jnp.float32(0.0))
        gnorm = jnp.sqrt(jax.lax.psum(sq, axes))
        scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-12))
        lr, c1b, c2b = adamw_scalars(oc, opt["step"])

        p_flat, tdef = jax.tree.flatten(params)
        g_flat = tdef.flatten_up_to(grads)
        mu_flat = tdef.flatten_up_to(opt["mu"])
        nu_flat = tdef.flatten_up_to(opt["nu"])
        ld_flat = tdef.flatten_up_to(
            {k: jax.tree.map(lambda _: lead[k], v)
             for k, v in params.items()})
        out = [adamw_leaf(pp, g * scale, mu, nu, lr, c1b, c2b, oc,
                          decay=(pp.ndim - ld) >= 2)
               for pp, g, mu, nu, ld
               in zip(p_flat, g_flat, mu_flat, nu_flat, ld_flat)]
        unflat = lambda i: jax.tree.unflatten(tdef, [o[i] for o in out])
        opt2 = {"mu": unflat(1), "nu": unflat(2), "step": opt["step"] + 1}
        return unflat(0), opt2, loss, gnorm

    rep = P()
    fn = shard_map(
        train, mesh=mesh,
        in_specs=(sp, ospec, rep, rep),
        out_specs=(sp, ospec, rep, rep),
        check_rep=False,
    )
    return jax.jit(fn)
