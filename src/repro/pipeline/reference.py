"""Schedule-table reference executor (single process).

Replays a schedule produced by ``repro.core.schedule`` with the *real*
fine-grained unit math of ``repro.models.model``: every F / B / W component
(braided or not) runs in exactly the table's device order, with activations,
forward contexts and weight tapes held in per-(vs, mb) buffers and the
"V"-shape dataflow routed between virtual stages.

This is the numerics oracle for the paper's braided F/B/W decomposition:
``pipeline_grads(...)`` must equal ``jax.grad`` of the monolithic loss for
*any* schedule kind and any architecture.  The SPMD executor is validated
against it in turn.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.schedule import partition
from repro.core.simulator import Instr, Placement
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.tp.context import TPContext


def _merge_grads(acc, new, scale=1.0):
    """Deep union-merge of (possibly partial) nested grad dicts: the joint
    grads (norm gains, core params) and the deferred weight-tape grads cover
    complementary sub-trees of each layer's parameter dict."""
    if isinstance(new, dict):
        acc = {} if acc is None else dict(acc)
        for k, v in new.items():
            acc[k] = _merge_grads(acc.get(k), v, scale)
        return acc
    if acc is None:
        return jax.tree.map(lambda x: x * scale, new)
    return jax.tree.map(lambda a, b: a + b * scale, acc, new)


def pipeline_grads(params, batches, tables, pl: Placement, cfg: ModelConfig,
                   tp: TPContext = TPContext(), part=None):
    """Execute a schedule table over ``m`` microbatches.

    params: canonical init_params output (unstacked blocks).
    batches: list of m microbatch dicts ({"tokens"/"embeds", "labels"}).
    part: per-virtual-stage (start, stop) layer ranges — defaults to the
    shared ``core.schedule.partition`` so this executor and the SPMD runtime
    agree on stage contents by construction.
    Returns (mean loss, grads pytree like params).
    """
    m = len(batches)
    n_vs = pl.n_vs
    bounds = partition(cfg, n_vs) if part is None else list(part)
    vs_params = [params["blocks"][a:b] for a, b in bounds]
    vs_specs = [cfg.layers[a:b] for a, b in bounds]
    scale = 1.0 / m

    x_in: dict = {}          # (vs, mb) -> activation
    g_in: dict = {}          # (vs, mb) -> upstream grad
    ctxs: dict = {}          # (vs, mb) -> fwd contexts
    embed_ctx: dict = {}     # mb -> embed ctx
    head_ctx: dict = {}      # mb -> head ctx
    tapes: dict = {}         # (vs, mb) -> weight tape
    head_tape: dict = {}
    losses = [None] * m
    g_blocks = [None] * cfg.n_layers
    g_embed = None
    g_head_lm = None
    g_head_joint = None

    rope_cache: dict = {}

    def rope_for(seq):
        if seq not in rope_cache:
            rope_cache[seq] = M._rope_for(cfg, seq)
        return rope_cache[seq]

    def run_f(vs, mb):
        if vs == 0:
            x, ec = M.embed_fwd(params["embed"], batches[mb], cfg)
            embed_ctx[mb] = ec
        else:
            x = x_in.pop((vs, mb))
        rope = rope_for(x.shape[1])
        y, cs = M.chunk_fwd(vs_params[vs], tp, x, rope, vs_specs[vs], cfg)
        ctxs[(vs, mb)] = cs
        if vs == n_vs - 1:
            loss, hc = M.head_fwd(params["head"], tp, y,
                                  batches[mb]["labels"], cfg)
            losses[mb] = loss
            head_ctx[mb] = hc
        else:
            x_in[(vs + 1, mb)] = y

    def run_b(vs, mb):
        nonlocal g_embed, g_head_lm, g_head_joint
        if vs == n_vs - 1:
            gx, h_tape, h_joint = M.head_bwd_act(
                params["head"], tp, head_ctx.pop(mb), jnp.float32(1.0), cfg)
            head_tape[mb] = h_tape
            g_head_joint = _merge_grads(g_head_joint, h_joint, scale)
            gy = gx
        else:
            gy = g_in.pop((vs, mb))
        gx, wts, joints = M.chunk_bwd_act(vs_params[vs], tp,
                                          ctxs.pop((vs, mb)), gy,
                                          vs_specs[vs], cfg)
        tapes[(vs, mb)] = wts
        a, _ = bounds[vs]
        for i, j in enumerate(joints):
            g_blocks[a + i] = _merge_grads(g_blocks[a + i], j, scale)
        if vs == 0:
            ge = M.embed_bwd_weight(params["embed"], embed_ctx.pop(mb), gx)
            g_embed = _merge_grads(g_embed, ge, scale)
        else:
            g_in[(vs - 1, mb)] = gx

    def run_w(vs, mb):
        nonlocal g_head_lm
        wts = tapes.pop((vs, mb))
        gws = M.chunk_bwd_weight(wts, vs_specs[vs])
        a, _ = bounds[vs]
        for i, gw in enumerate(gws):
            g_blocks[a + i] = _merge_grads(g_blocks[a + i], gw, scale)
        if vs == n_vs - 1 and mb in head_tape:
            gh = M.head_bwd_weight(head_tape.pop(mb))
            g_head_lm = _merge_grads(g_head_lm, gh, scale)

    # Execute in a *global* feasible order: round-robin the per-device
    # streams, running each device's next instruction once its inputs exist.
    ptr = [0] * pl.p
    remaining = sum(len(t) for t in tables)
    stall = 0
    while remaining:
        progressed = False
        for d in range(pl.p):
            if ptr[d] >= len(tables[d]):
                continue
            ins: Instr = tables[d][ptr[d]]
            # feasibility: inputs present?
            ok = True
            if ins.f is not None:
                vs, mb = ins.f
                if vs > 0 and (vs, mb) not in x_in:
                    ok = False
            if ok and ins.b is not None:
                vs, mb = ins.b
                if vs == n_vs - 1:
                    if mb not in head_ctx and ins.f != (vs, mb):
                        ok = False
                elif (vs, mb) not in g_in:
                    ok = False
            if ok and ins.w is not None and ins.w != ins.b:
                if ins.w not in tapes:
                    ok = False
            if not ok:
                continue
            # run components in braid order: F units first, then B, then W.
            if ins.f is not None:
                run_f(*ins.f)
            if ins.b is not None:
                run_b(*ins.b)
            if ins.w is not None:
                run_w(*ins.w)
            ptr[d] += 1
            remaining -= 1
            progressed = True
        if not progressed:
            stall += 1
            if stall > 2:
                raise RuntimeError(
                    "pipeline reference executor stalled; next instrs: "
                    + str([tables[d][ptr[d]] if ptr[d] < len(tables[d])
                           else None for d in range(pl.p)]))
        else:
            stall = 0

    # params unused by the graph (e.g. the token table of an embed-frontend
    # arch) get explicit zero grads, matching jax.grad's structure.
    g_embed_full = jax.tree.map(lambda x: jnp.zeros_like(x),
                                params["embed"])
    grads = {
        "embed": _merge_grads(g_embed_full, g_embed or {}, 1.0),
        "blocks": g_blocks,
        "head": {**(g_head_lm or {}), **(g_head_joint or {})},
    }
    loss = sum(losses) * scale
    return loss, grads


def reference_grads(params, batches, cfg: ModelConfig,
                    tp: TPContext = TPContext()):
    """Monolithic jax.grad oracle over the same microbatches (mean loss)."""
    m = len(batches)

    def total_loss(p):
        period = M.period_of(cfg)
        stacked = {"embed": p["embed"],
                   "blocks": M.stack_blocks(p["blocks"], period),
                   "head": p["head"]}
        return sum(M.loss_fn(stacked, b, cfg, tp=tp) for b in batches) / m

    return jax.value_and_grad(total_loss)(params)
