"""Pipeline-parallel runtime: schedule-table executors.

* ``reference`` — single-process executor that replays any schedule table
  with the real per-unit F/B/W math (any architecture, braiding semantics,
  V-shape routing).  Numerics oracle: grads must equal ``jax.grad``.
* ``spmd`` — shard_map executor over a real ``stage`` mesh axis with
  ``ppermute`` stage communication; one scanned SPMD program executes the
  per-device instruction streams in lockstep slots.
"""
from repro.pipeline.reference import pipeline_grads, reference_grads
