"""Pipeline-parallel runtime: schedule-table executors.

* ``reference`` — single-process executor that replays any schedule table
  with the real per-unit F/B/W math (any architecture, braiding semantics,
  any placement routing).  Numerics oracle: grads must equal ``jax.grad``.
* ``slots`` — placement-generic lowering of verified instruction tables to
  lockstep slot grids + ``lax.switch`` branch codes (flat / parallel /
  vshape wiring).
* ``spmd`` — shard_map executor over a real ``stage`` mesh axis with
  ``ppermute`` stage communication; one scanned SPMD program executes the
  per-device instruction streams of any of the six schedule kinds in
  lockstep slots.
"""
from repro.pipeline.reference import pipeline_grads, reference_grads
