"""Tensor-parallel execution context.

Two call modes share one model definition (single source of truth — the unit
forward functions in ``repro.models.units``):

* **unit mode** (``axis`` set): running under ``shard_map`` over the TP mesh
  axis.  Params are per-rank shards; the unit code places the paper's
  collectives explicitly — the ``f``/``g`` operators of Fig. 2 — and applies
  the Eq. (1) residual fusion ``AR(partial + detach(res)/t)``.
* **pjit mode** (``axis`` is None): global-view arrays under ``pjit`` with
  sharding constraints; XLA SPMD inserts the collectives.  ``psum`` is the
  identity and the residual is added plainly (no detach — gradient flows
  through the residual normally, which is what Eq. (2)'s "+1" reproduces by
  hand in unit mode).

Overlap-aware decomposition (§4, Fig. 1)
----------------------------------------

``psum`` is a single blocking ``lax.psum``; nothing can hide under it.  The
decomposed form expresses the same all-reduce as a ring reduce-scatter
followed by a ring all-gather, built from ``lax.ppermute`` hops over tiles of
the feature dimension — ``2*(t-1)`` hops of ``F/t`` elements each.  The split
``start_psum``/``finish_psum`` API returns a :class:`PendingPsum` whose hops
are traced lazily, one per ``step()``, so an executor can issue partner-chunk
matmuls *between* the hops of an in-flight reduction (the braided composite
executor in ``repro.models.model.chunk_fwd_bwd_braided`` does exactly this).

Units never call the ring form directly.  Instead every unit routes its
*output* collective — the last psum of a unit, whose result is only consumed
by the *next* unit — through ``psum_out``/``fuse_residual``.  On the base
context both are the monolithic reference path; :class:`OverlapTP` overrides
exactly those two hooks to return pendings, leaving interior collectives
(mamba's bcdt reduce, MoE's expert combine, attention's joint-grad psums, the
vocab-parallel softmax stats) blocking, since their results are consumed
immediately inside the unit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


def _ring_hop(x, axis: str, t: int, safe: bool):
    """One +1 ring shift over ``axis``: rank ``r`` receives rank
    ``(r - 1) % t``'s value.

    ``safe=False`` is a single ``ppermute`` — the bandwidth-optimal form,
    but XLA:CPU rendezvouses collective-permute over *all* devices of the
    computation (not just the ``source_target_pairs`` group), so it
    deadlocks whenever only some mesh rows reach it — e.g. inside a
    ``lax.switch`` arm of the pipeline's slot dispatch, where stage rows
    take different branches.  ``safe=True`` emulates the shift with a
    one-hot masked ``psum``: all-reduce rendezvous is per replica group,
    so disjoint TP groups may execute it independently, at ``t`` x the hop
    bandwidth.  Values are identical (each output slot has exactly one
    non-zero contributor).
    """
    if not safe:
        return jax.lax.ppermute(x, axis, [(i, (i + 1) % t) for i in range(t)])
    r = jax.lax.axis_index(axis)
    sel = (jnp.arange(t) == (r + 1) % t).reshape((t,) + (1,) * x.ndim)
    g = jax.lax.psum(jnp.where(sel, x[None], jnp.zeros((), x.dtype)), axis)
    return jax.lax.dynamic_index_in_dim(g, r, 0, keepdims=False)


def _ring_psum_stages(axis: str, t: int, x, ax: int, safe: bool = False):
    """Generator tracing one ring hop per ``next()``; the final ``next()``
    yields the fully reduced array (all earlier ones yield ``None``).

    Reduce-scatter: tile the reduced axis into ``t`` chunks; after hop ``s``
    rank ``r`` holds the partial sum of tile ``(r - s) % t`` over ranks
    ``{r-s, ..., r}``, so after ``t-1`` hops it owns tile ``(r+1) % t``
    fully reduced.  All-gather: circulate the owned tiles the rest of the way
    round the ring, scattering each into its slot of the output.
    """
    r = jax.lax.axis_index(axis)
    xs = jnp.stack(jnp.split(x, t, axis=ax))        # (t, ..., F/t, ...)

    def tile(i):
        return jax.lax.dynamic_index_in_dim(xs, i % t, 0, keepdims=False)

    acc = tile(r)
    for s in range(1, t):
        acc = _ring_hop(acc, axis, t, safe) + tile(r - s)
        yield None
    out = jnp.zeros_like(xs)
    out = jax.lax.dynamic_update_index_in_dim(out, acc, (r + 1) % t, 0)
    buf = acc
    for s in range(1, t):
        buf = _ring_hop(buf, axis, t, safe)
        out = jax.lax.dynamic_update_index_in_dim(out, buf, (r - s + 1) % t, 0)
        yield None
    yield jnp.concatenate([out[i] for i in range(t)], axis=ax)


class PendingPsum:
    """An all-reduce in flight, decomposed into ring hops.

    ``step()`` traces one hop (a ``ppermute`` plus a tile add, or the final
    reassembly); ``finish()`` runs whatever hops remain and returns the
    reduced value.  Degenerate cases — no TP axis, ``size == 1``, or a tile
    axis not divisible by ``size`` — fall back to the monolithic collective
    and complete in a single step, so callers can treat every unit-output
    collective uniformly.
    """

    def __init__(self, axis: Optional[str], size: int, x, tile_axis: int = -1,
                 safe: bool = False):
        self.axis, self.size = axis, size
        self.n_steps = 0
        self._value = None
        if axis is None:
            self._gen = iter([x])
        else:
            ax = tile_axis % x.ndim
            if size == 1 or x.shape[ax] == 0 or x.shape[ax] % size:
                self._gen = iter([jax.lax.psum(x, axis)])
            else:
                self._gen = _ring_psum_stages(axis, size, x, ax, safe)

    @property
    def done(self) -> bool:
        return self._value is not None

    def step(self) -> "PendingPsum":
        """Trace one ring hop (no-op once complete)."""
        if self._value is None:
            nxt = next(self._gen)
            self.n_steps += 1
            if nxt is not None:
                self._value = nxt
        return self

    def finish(self):
        while self._value is None:
            self.step()
        return self._value


@dataclass(frozen=True)
class TPContext:
    axis: Optional[str] = None
    size: int = 1
    # Ring hops as one-hot masked psums instead of ppermute — required when
    # the ring may execute inside divergent control flow (different mesh
    # rows taking different ``lax.switch`` arms): XLA:CPU collective-permute
    # rendezvouses over all devices and deadlocks there, while all-reduce
    # rendezvous is per replica group.  See ``_ring_hop``.
    safe_ring: bool = False
    # Expert-parallel axis: MoE experts shard their leading E dim over this
    # mesh axis while activations and routing stay replicated across it, so
    # routing (and capacity-overflow drops) are bitwise identical to EP=1.
    expert_axis: Optional[str] = None
    expert_size: int = 1

    def psum(self, x):
        if self.axis is None:
            return x
        return jax.lax.psum(x, self.axis)

    def psum_out(self, x):
        """The unit-OUTPUT collective: the last psum of a bwd_act unit, whose
        result feeds only the *next* unit.  Identical to ``psum`` here; the
        hook exists so :class:`OverlapTP` can defer exactly these."""
        return self.psum(x)

    def pmax(self, x):
        if self.axis is None:
            return x
        return jax.lax.pmax(x, self.axis)

    def axis_index(self):
        if self.axis is None:
            return jnp.int32(0)
        return jax.lax.axis_index(self.axis)

    def fuse_residual(self, partial, residual):
        """Eq. (1): the unit output collective with the residual fused in.

        unit mode: AR(partial + detach(residual)/t) — each of the t ranks
        contributes residual/t, summing back to exactly ``residual``; the
        gradient of the residual branch is re-attached in ``bwd_act`` as the
        "+1" term of Eq. (2).
        pjit mode: plain ``partial + residual``.
        """
        if self.axis is None:
            return partial + residual
        return jax.lax.psum(
            partial + jax.lax.stop_gradient(residual) / self.size, self.axis)

    # ---- decomposed (ring) forms ---------------------------------------

    def start_psum(self, x, tile_axis: int = -1) -> PendingPsum:
        """Begin a decomposed all-reduce; hops trace on ``step()``/``finish()``."""
        return PendingPsum(self.axis, self.size, x, tile_axis,
                           safe=self.safe_ring)

    def finish_psum(self, pending: PendingPsum):
        return pending.finish()

    def ring_psum(self, x, tile_axis: int = -1):
        """Monolithic-equivalent convenience: start + finish in one call.
        Bitwise equal to ``psum`` at ``size <= 2``; reassociated (so only
        approximately equal) beyond that."""
        return self.start_psum(x, tile_axis).finish()

    def start_fused_residual(self, partial, residual,
                             tile_axis: int = -1) -> PendingPsum:
        """Ring form of Eq. (1): defer ``fuse_residual`` as a PendingPsum."""
        if self.axis is None:
            return PendingPsum(None, 1, partial + residual)
        return PendingPsum(
            self.axis, self.size,
            partial + jax.lax.stop_gradient(residual) / self.size, tile_axis,
            safe=self.safe_ring)

    # ---- expert-parallel forms -----------------------------------------

    def ep_index(self):
        if self.expert_axis is None:
            return jnp.int32(0)
        return jax.lax.axis_index(self.expert_axis)

    def ep_slice(self, x, edim: int):
        """Full expert-dim buffer -> this rank's contiguous expert slice
        (rank r owns experts [r*E/ep, (r+1)*E/ep)).

        ``expert_size`` governs shapes and ``expert_axis`` the collectives:
        with size set but no axis (shape tracing under ``eval_shape``, which
        cannot bind mesh axis names) this is a static rank-0 slice."""
        if self.expert_size == 1:
            return x
        e_local = x.shape[edim] // self.expert_size
        return jax.lax.dynamic_slice_in_dim(
            x, self.ep_index() * e_local, e_local, edim)

    def ep_all_gather(self, x, edim: int):
        """Local expert slice -> the full expert-dim buffer, replicated over
        the expert axis.  This is the combine-side collective of expert
        parallelism (the dispatch side is a local slice here because the
        token buffers are replicated across the axis).

        ``safe_ring=True`` emulates the all-gather with a masked psum (one
        contributor per expert slot — exact) for the same divergent-control-
        flow reason as ``_ring_hop``; otherwise a real tiled ``all_gather``.
        Axis-less mode (shape tracing) tiles the local slice.
        """
        if self.expert_size == 1:
            return x
        if self.expert_axis is None:
            return jnp.concatenate([x] * self.expert_size, axis=edim)
        if not self.safe_ring:
            return jax.lax.all_gather(x, self.expert_axis, axis=edim,
                                      tiled=True)
        e_local = x.shape[edim]
        full = list(x.shape)
        full[edim] = e_local * self.expert_size
        buf = jax.lax.dynamic_update_slice_in_dim(
            jnp.zeros(full, x.dtype), x, self.ep_index() * e_local, edim)
        return jax.lax.psum(buf, self.expert_axis)


class OverlapTP:
    """Deferring proxy over a :class:`TPContext` for the braided executor.

    Unit-output collectives (``fuse_residual`` / ``psum_out``) come back as
    :class:`PendingPsum` objects whose ring hops the caller schedules between
    partner-chunk matmuls; everything else (interior ``psum``, ``pmax``,
    ``axis_index``) stays blocking and delegates to the base context.
    """

    def __init__(self, base: TPContext):
        self.base = base

    @property
    def axis(self):
        return self.base.axis

    @property
    def size(self):
        return self.base.size

    def psum(self, x):
        return self.base.psum(x)

    def pmax(self, x):
        return self.base.pmax(x)

    def axis_index(self):
        return self.base.axis_index()

    def fuse_residual(self, partial, residual) -> PendingPsum:
        return self.base.start_fused_residual(partial, residual)

    def psum_out(self, x) -> PendingPsum:
        return self.base.start_psum(x)

    @property
    def expert_axis(self):
        return self.base.expert_axis

    @property
    def expert_size(self):
        return self.base.expert_size

    def ep_index(self):
        return self.base.ep_index()

    def ep_slice(self, x, edim: int):
        return self.base.ep_slice(x, edim)

    def ep_all_gather(self, x, edim: int):
        return self.base.ep_all_gather(x, edim)
