"""Tensor-parallel execution context.

Two call modes share one model definition (single source of truth — the unit
forward functions in ``repro.models.units``):

* **unit mode** (``axis`` set): running under ``shard_map`` over the TP mesh
  axis.  Params are per-rank shards; the unit code places the paper's
  collectives explicitly — the ``f``/``g`` operators of Fig. 2 — and applies
  the Eq. (1) residual fusion ``AR(partial + detach(res)/t)``.
* **pjit mode** (``axis`` is None): global-view arrays under ``pjit`` with
  sharding constraints; XLA SPMD inserts the collectives.  ``psum`` is the
  identity and the residual is added plainly (no detach — gradient flows
  through the residual normally, which is what Eq. (2)'s "+1" reproduces by
  hand in unit mode).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class TPContext:
    axis: Optional[str] = None
    size: int = 1

    def psum(self, x):
        if self.axis is None:
            return x
        return jax.lax.psum(x, self.axis)

    def pmax(self, x):
        if self.axis is None:
            return x
        return jax.lax.pmax(x, self.axis)

    def axis_index(self):
        if self.axis is None:
            return jnp.int32(0)
        return jax.lax.axis_index(self.axis)

    def fuse_residual(self, partial, residual):
        """Eq. (1): the unit output collective with the residual fused in.

        unit mode: AR(partial + detach(residual)/t) — each of the t ranks
        contributes residual/t, summing back to exactly ``residual``; the
        gradient of the residual branch is re-attached in ``bwd_act`` as the
        "+1" term of Eq. (2).
        pjit mode: plain ``partial + residual``.
        """
        if self.axis is None:
            return partial + residual
        return jax.lax.psum(
            partial + jax.lax.stop_gradient(residual) / self.size, self.axis)
