from repro.tp.context import TPContext

__all__ = ["TPContext"]
