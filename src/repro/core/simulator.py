"""Event-driven pipeline-schedule simulator.

This is the quantitative engine behind the paper's tables and figures: given
per-virtual-stage unit times (T_F, T_B, T_W, T_AR, M_a) it executes a
schedule — a per-device ordered list of :class:`Instr` — respecting

  * in-order execution per device,
  * cross-stage dataflow (F needs upstream F, B needs downstream B),
  * the TP-exposure rules of §3 (which collectives an instruction hides).

and reports iteration time, per-device PP bubbles, exposed TP communication
and peak activation memory.  The same engine *generates* the greedy
policy-driven schedules (ZB-V, STP) in ``repro.core.schedule``.

Instruction kinds and their TP-exposure model (Fig. 2/3):

  ``F``    standalone forward                      -> exposes T_AR
  ``B``    decoupled activation backward           -> exposes T_AR
  ``BW``   full backward (B + own W)               -> AR hidden under W
  ``W``    deferred weight gradient                -> no collective
  ``FB``   braided fwd + decoupled bwd  (Fig. 3b)  -> both ARs hidden
  ``FBW``  braided fwd + full bwd       (Fig. 3a)  -> all ARs hidden
  ``FW``   braided fwd + stored W                  -> F's AR hidden under W
  ``BWx``  decoupled bwd braided w/ stored W       -> B's AR hidden under W

Exposure is a property of the *schedule kind* (the paper's point): plain
schedules issue ops sequentially on the compute stream so a decoupled B's AR
is exposed even if a W happens to follow; only the braided launch structure
legally overlaps them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal, Optional, Sequence

import numpy as np

Phase = Literal["F", "B", "W"]
Kind = Literal["F", "B", "BW", "W", "FB", "FBW", "FW", "BWx"]


# ---------------------------------------------------------------------------
# Virtual-stage placements.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Placement:
    """Maps virtual stages -> devices.  n_vs = v * p."""
    p: int
    v: int
    kind: Literal["flat", "parallel", "vshape"]

    def __post_init__(self):
        if self.p < 2:
            raise ValueError(
                f"Placement needs p >= 2 pipeline stages, got p={self.p}: "
                "a single-stage pipeline has no neighbour exchange (the "
                "SPMD executor would build empty ppermute perms and "
                "silently zero its boundary streams); run the pjit "
                "runtime instead")

    @property
    def n_vs(self) -> int:
        return self.p * self.v

    def device(self, vs: int) -> int:
        if self.kind == "flat":            # v = 1
            return vs
        if self.kind == "parallel":        # 1F1B-I: chunk c stage s -> dev s
            return vs % self.p
        # vshape: chunk 0 ascending, chunk 1 descending (loss on device 0)
        return vs if vs < self.p else 2 * self.p - 1 - vs

    def chunk(self, vs: int) -> int:
        if self.kind == "flat":
            return 0
        return vs // self.p

    def vs_of(self, device: int, chunk: int) -> int:
        if self.kind == "flat":
            return device
        if self.kind == "parallel":
            return chunk * self.p + device
        return device if chunk == 0 else 2 * self.p - 1 - device


def flat(p: int) -> Placement:
    return Placement(p, 1, "flat")


def parallel(p: int) -> Placement:
    return Placement(p, 2, "parallel")


def vshape(p: int) -> Placement:
    return Placement(p, 2, "vshape")


# ---------------------------------------------------------------------------
# Times and instructions.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StageTimes:
    """Per-virtual-stage unit times; arrays of shape (n_vs,)."""
    t_f: np.ndarray
    t_b: np.ndarray
    t_w: np.ndarray
    t_ar: np.ndarray
    m_a: np.ndarray
    t_comm: float = 0.0           # explicit PP hop latency

    @staticmethod
    def uniform(n_vs: int, *, t_f=2.0, t_b=2.0, t_w=1.0, t_ar=0.5, m_a=1.0,
                t_comm=0.0) -> "StageTimes":
        one = np.ones(n_vs)
        return StageTimes(t_f * one, t_b * one, t_w * one, t_ar * one,
                          m_a * one, t_comm)

    def scaled_vs(self, vs: int, factor: float) -> "StageTimes":
        """Scale one virtual stage's compute (MLLM ViT imbalance)."""
        def s(a):
            a = a.copy()
            a[vs] = a[vs] * factor
            return a
        return StageTimes(s(self.t_f), s(self.t_b), s(self.t_w),
                          s(self.t_ar), s(self.m_a), self.t_comm)


@dataclass(frozen=True)
class Instr:
    kind: Kind
    f: Optional[tuple[int, int]] = None    # (vs, mb)
    b: Optional[tuple[int, int]] = None
    w: Optional[tuple[int, int]] = None

    def components(self):
        if self.f is not None:
            yield ("F", *self.f)
        if self.b is not None:
            yield ("B", *self.b)
        if self.w is not None:
            yield ("W", *self.w)


@dataclass(frozen=True)
class OffloadOp:
    """Explicit activation-buffer lifetime op in a schedule table (§4.4).

    ``OFFLOAD`` moves fraction α of the (vs, mb) activation to host right
    after its F; ``FETCH`` brings it back ahead of its B.  Between the two,
    the device holds only ``(1-α)·m_a`` of that activation.  These ops carry
    no timing of their own — :func:`simulate` models the cost through its
    ``offload_alpha`` / ``offload_overhead`` parameters and strips them —
    but :func:`verify_tables` replays them as part of the IR safety
    contract, and the SPMD executor lowers them to real host transfers.
    """
    op: Literal["OFFLOAD", "FETCH"]
    vs: int
    mb: int


def annotate_offload(tables, pl: "Placement"):
    """Derive the §4.4 activation-offload lifetime ops from a schedule
    table: an ``OFFLOAD(vs, mb)`` immediately after each instruction whose
    F-part targets a *chunk-0* virtual stage, and a ``FETCH(vs, mb)``
    immediately before the instruction whose B-part consumes it.  Chunk-1
    activations are short-lived and stay resident (the paper's PCIe-
    contention rule).  This changes no dispatch policy — the per-device
    instruction order is exactly the input table's.

    Self-braided instructions (F and B of the same (vs, mb) in one block)
    create and free the activation within the instruction, so they get no
    lifetime ops."""
    out = []
    for tab in tables:
        ops: list = []
        for ins in tab:
            if isinstance(ins, OffloadOp):
                raise ValueError("table already carries offload ops")
            if (ins.b is not None and pl.chunk(ins.b[0]) == 0
                    and ins.b != ins.f):
                ops.append(OffloadOp("FETCH", *ins.b))
            ops.append(ins)
            if (ins.f is not None and pl.chunk(ins.f[0]) == 0
                    and ins.f != ins.b):
                ops.append(OffloadOp("OFFLOAD", *ins.f))
        out.append(ops)
    return out


def strip_offload(tables):
    """Drop :class:`OffloadOp` entries, leaving the pure instruction table
    (what :func:`simulate` and the slot lowering consume)."""
    return [[ins for ins in tab if not isinstance(ins, OffloadOp)]
            for tab in tables]


def instr_dep_keys(instr: Instr, n_vs: int):
    """Cross-instruction dependencies of one instruction — the single
    source of the IR dataflow rule, shared by the static verifier and the
    slot lowering: F needs the upstream F; B needs the downstream B (or its
    own F at the loss stage, unless self-braided); a W with a foreign tape
    needs that tape's B.  Yields ((phase, vs, mb), tag) with tag ``"tape"``
    for the W entry (same-device, so consumers may relax its slot timing)
    and ``"flow"`` otherwise."""
    if instr.f is not None:
        vs, mb = instr.f
        if vs > 0:
            yield ("F", vs - 1, mb), "flow"
    if instr.b is not None:
        vs, mb = instr.b
        if vs < n_vs - 1:
            yield ("B", vs + 1, mb), "flow"
        elif instr.f != (vs, mb):           # loss vs: needs own F
            yield ("F", vs, mb), "flow"     # (self-braid F&B carries it)
    if instr.w is not None and instr.w != instr.b:   # own-B W is in-instr
        yield ("B", *instr.w), "tape"


def duration(instr: Instr, t: StageTimes) -> tuple[float, float]:
    """Returns (total duration, exposed TP communication within it)."""
    d = 0.0
    if instr.f is not None:
        d += t.t_f[instr.f[0]]
    if instr.b is not None:
        d += t.t_b[instr.b[0]]
    if instr.w is not None:
        d += t.t_w[instr.w[0]]
    k = instr.kind
    if k == "F":
        return d + t.t_ar[instr.f[0]], t.t_ar[instr.f[0]]
    if k == "B":
        return d + t.t_ar[instr.b[0]], t.t_ar[instr.b[0]]
    if k == "BW":                       # AR hidden under own W
        exp = max(0.0, t.t_ar[instr.b[0]] - t.t_w[instr.w[0]])
        return d + exp, exp
    if k == "W":
        return d, 0.0
    if k == "FB":                       # braided: both ARs hidden
        ar = t.t_ar[instr.f[0]] + t.t_ar[instr.b[0]]
        comp = d
        exp = max(0.0, ar - comp)
        return comp + exp, exp
    if k == "FBW":
        ar = t.t_ar[instr.f[0]] + t.t_ar[instr.b[0]]
        exp = max(0.0, ar - d)
        return d + exp, exp
    if k == "FW":                       # F's AR hidden under the W
        exp = max(0.0, t.t_ar[instr.f[0]] - t.t_w[instr.w[0]])
        return d + exp, exp
    if k == "BWx":                      # B's AR hidden under foreign W
        exp = max(0.0, t.t_ar[instr.b[0]] - t.t_w[instr.w[0]])
        return d + exp, exp
    raise ValueError(k)


# ---------------------------------------------------------------------------
# Simulation result.
# ---------------------------------------------------------------------------

@dataclass
class SimResult:
    total_time: float
    busy: np.ndarray                 # per device, incl. exposed AR
    tp_exposed: np.ndarray           # per device
    peak_mem: np.ndarray             # per device, in M_a units
    finish: dict                     # (phase, vs, mb) -> time
    trace: list                      # (device, start, end, instr)
    p: int
    m: int

    @property
    def pp_bubble(self) -> np.ndarray:
        return self.total_time - self.busy

    def summary(self) -> dict:
        return {
            "total_time": self.total_time,
            "pp_bubble_max": float(self.pp_bubble.max()),
            "pp_bubble_mean": float(self.pp_bubble.mean()),
            "tp_exposed_max": float(self.tp_exposed.max()),
            "tp_exposed_mean": float(self.tp_exposed.mean()),
            "peak_mem_max": float(self.peak_mem.max()),
            "peak_mem_mean": float(self.peak_mem.mean()),
            "peak_mem": [float(x) for x in self.peak_mem],
        }


# ---------------------------------------------------------------------------
# Core engine: replay a per-device instruction table.
# ---------------------------------------------------------------------------

def _dep_times(instr: Instr, pl: Placement, t: StageTimes, finish: dict,
               m: int):
    """Latest upstream completion needed before ``instr`` may start; None if
    some dependency has not finished yet.

    Braided blocks (FB/FBW) execute their F units first, so the B-part's
    upstream gradient only needs to arrive ``t_f[f_vs]`` into the block —
    this is exactly the paper's interleaving window (Fig. 3)."""
    deps = []
    n_vs = pl.n_vs
    b_slack = 0.0
    if instr.f is not None:
        vs, mb = instr.f
        if instr.kind in ("FB", "FBW"):
            b_slack = t.t_f[vs]
        if vs > 0:
            key = ("F", vs - 1, mb)
            if key not in finish:
                return None
            hop = t.t_comm if pl.device(vs - 1) != pl.device(vs) else 0.0
            deps.append(finish[key] + hop)
    if instr.b is not None:
        vs, mb = instr.b
        if vs < n_vs - 1:
            key = ("B", vs + 1, mb)
            if key not in finish:
                return None
            hop = t.t_comm if pl.device(vs + 1) != pl.device(vs) else 0.0
            deps.append(finish[key] + hop - b_slack)
        elif instr.f != (vs, mb):           # loss vs: needs own F
            key = ("F", vs, mb)             # (self-braid F&B carries it)
            if key not in finish:
                return None
            deps.append(finish[key] - b_slack)
    if instr.w is not None and instr.w != instr.b:   # own-B W is in-instr
        key = ("B", *instr.w)
        if key not in finish:
            return None
        deps.append(finish[key])
    return max(deps, default=0.0)


def simulate(schedule: Sequence[Sequence[Instr]], pl: Placement,
             t: StageTimes, m: int, *, offload_alpha: float = 0.0,
             offload_overhead: float = 0.0) -> SimResult:
    """Replay ``schedule`` (per-device in-order lists).

    ``offload_alpha`` models the §4.4 enhanced variant: a fraction α of each
    *chunk-0* activation is offloaded to host in parallel with compute
    (chunk-1 activations have short lifespans and are skipped to avoid PCIe
    contention), so an F of a chunk-0 virtual stage only holds (1-α)·M_a.
    The paper constrains the offload time below T_F, so the throughput cost
    is a small per-F ``offload_overhead`` (CPU-side, default 0).

    Tables annotated with :class:`OffloadOp` lifetime ops are accepted; the
    ops are stripped up front (they carry no timing — the α/overhead
    parameters above are the timing model)."""
    schedule = strip_offload(schedule)
    n_dev = pl.p
    free = np.zeros(n_dev)
    ptr = [0] * n_dev
    finish: dict = {}
    busy = np.zeros(n_dev)
    tp_exposed = np.zeros(n_dev)
    mem = np.zeros(n_dev)
    peak = np.zeros(n_dev)
    trace = []
    remaining = sum(len(s) for s in schedule)

    while remaining:
        progressed = False
        # earliest feasible dispatch across devices
        best = None
        for d in range(n_dev):
            if ptr[d] >= len(schedule[d]):
                continue
            instr = schedule[d][ptr[d]]
            dep = _dep_times(instr, pl, t, finish, m)
            if dep is None:
                continue
            start = max(free[d], dep)
            if best is None or start < best[0]:
                best = (start, d, instr)
        if best is None:
            raise RuntimeError(
                "schedule deadlock: no instruction dispatchable; next per "
                "device: " + str([schedule[d][ptr[d]] if ptr[d] < len(
                    schedule[d]) else None for d in range(n_dev)]))
        start, d, instr = best
        dur, exp = duration(instr, t)
        if offload_overhead and instr.f is not None \
                and pl.chunk(instr.f[0]) == 0:
            dur += offload_overhead
        end = start + dur
        for ph, vs, mb in instr.components():
            finish[(ph, vs, mb)] = end
            held = t.m_a[vs] * (1.0 - offload_alpha
                                if pl.chunk(vs) == 0 else 1.0)
            if ph == "F":
                mem[d] += held
                peak[d] = max(peak[d], mem[d])
            elif ph == "B":
                mem[d] -= held
        free[d] = end
        busy[d] += dur
        tp_exposed[d] += exp
        trace.append((d, start, end, instr))
        ptr[d] += 1
        remaining -= 1

    return SimResult(total_time=float(free.max()), busy=busy,
                     tp_exposed=tp_exposed, peak_mem=peak, finish=finish,
                     trace=trace, p=pl.p, m=m)


# ---------------------------------------------------------------------------
# Static IR verification: the lowering contract the executors rely on.
# ---------------------------------------------------------------------------

class ScheduleVerificationError(AssertionError):
    """A schedule table violates the instruction-IR safety contract."""


def verify_tables(schedule: Sequence[Sequence[Instr]], pl: Placement, m: int,
                  *, mem_bound: Optional[float] = None,
                  m_a: Optional[np.ndarray] = None,
                  offload_alpha: float = 0.0) -> np.ndarray:
    """Statically verify a per-device instruction table as an IR program.

    Checks, without any timing model (pure dependency replay):

      * completeness/uniqueness — every (phase, vs, mb) appears exactly once,
        on the device that owns ``vs``;
      * dependency safety — a global in-order replay of the per-device
        streams never deadlocks: each F's upstream activation, each B's
        downstream gradient (or own F at the loss stage) and each W's tape
        exist when the instruction dispatches;
      * memory safety — no double-free: a B releases its activation exactly
        once and a W consumes its tape exactly once (``BW``-style fused
        instructions consume inline); nothing is left allocated at the end;
      * offload lifetimes — tables may carry :class:`OffloadOp` entries (see
        :func:`annotate_offload`): an ``OFFLOAD`` needs its F done and a
        live, not-already-offloaded activation (no double-offload); a
        ``FETCH`` needs the activation offloaded (no fetch-before-offload /
        double-fetch); a B must not consume a still-offloaded activation
        (a missing FETCH is an offload leak), and nothing may remain
        offloaded at end of schedule.  Between OFFLOAD and FETCH the device
        holds only ``(1-offload_alpha)·m_a`` of the activation, so the
        ``mem_bound`` check is offload-aware;
      * memory bound — per-device peak in-flight activation memory (in
        ``m_a`` units, default 1 per virtual stage) stays <= ``mem_bound``.

    Returns the per-device peak in-flight activation memory.
    """
    n_dev, n_vs = pl.p, pl.n_vs
    if m_a is None:
        m_a = np.ones(n_vs)
    seen: dict = {}
    for d, tab in enumerate(schedule):
        for i, ins in enumerate(tab):
            if isinstance(ins, OffloadOp):
                continue
            for ph, vs, mb in ins.components():
                key = (ph, vs, mb)
                if key in seen:
                    raise ScheduleVerificationError(
                        f"duplicate op {key}: device {seen[key][0]} "
                        f"instr {seen[key][1]} and device {d} instr {i}")
                if not (0 <= vs < n_vs and 0 <= mb < m):
                    raise ScheduleVerificationError(f"out-of-range op {key}")
                if pl.device(vs) != d:
                    raise ScheduleVerificationError(
                        f"{key} scheduled on device {d}, "
                        f"owner is {pl.device(vs)}")
                seen[key] = (d, i)
    expect = 3 * n_vs * m
    if len(seen) != expect:
        missing = {(ph, vs, mb) for ph in "FBW" for vs in range(n_vs)
                   for mb in range(m)} - set(seen)
        raise ScheduleVerificationError(
            f"incomplete schedule: {len(seen)}/{expect} ops; "
            f"missing e.g. {sorted(missing)[:8]}")

    done: set = set()            # (phase, vs, mb) replayed
    tapes: set = set()           # (vs, mb) with a live weight tape
    acts: set = set()            # (vs, mb) with a live activation
    offloaded: set = set()       # (vs, mb) with the α-slice on host
    alpha = float(offload_alpha)
    mem = np.zeros(n_dev)
    peak = np.zeros(n_dev)
    ptr = [0] * n_dev

    def deps_ok(ins: Instr) -> bool:
        return all(key in done for key, _ in instr_dep_keys(ins, n_vs))

    remaining = sum(len(t) for t in schedule)
    while remaining:
        progressed = False
        for d in range(n_dev):
            if ptr[d] >= len(schedule[d]):
                continue
            ins = schedule[d][ptr[d]]
            if isinstance(ins, OffloadOp):
                vs, mb = ins.vs, ins.mb
                if not (0 <= vs < n_vs and 0 <= mb < m):
                    raise ScheduleVerificationError(
                        f"out-of-range {ins.op}({vs},{mb})")
                if pl.device(vs) != d:
                    raise ScheduleVerificationError(
                        f"{ins.op}({vs},{mb}) scheduled on device {d}, "
                        f"owner is {pl.device(vs)}")
                if ins.op == "OFFLOAD":
                    if ("F", vs, mb) not in done or (vs, mb) not in acts:
                        raise ScheduleVerificationError(
                            f"OFFLOAD({vs},{mb}) without a live activation "
                            "(its F has not run, or its B already freed it)")
                    if (vs, mb) in offloaded:
                        raise ScheduleVerificationError(
                            f"double-offload of activation ({vs},{mb})")
                    offloaded.add((vs, mb))
                    mem[d] -= alpha * m_a[vs]
                else:
                    if (vs, mb) not in offloaded:
                        raise ScheduleVerificationError(
                            f"FETCH({vs},{mb}) of an activation not "
                            "offloaded (fetch-before-offload or "
                            "double-fetch)")
                    offloaded.discard((vs, mb))
                    mem[d] += alpha * m_a[vs]
                    peak[d] = max(peak[d], mem[d])
                ptr[d] += 1
                remaining -= 1
                progressed = True
                continue
            if not deps_ok(ins):
                continue
            if ins.f is not None:
                vs, mb = ins.f
                done.add(("F", vs, mb))
                acts.add((vs, mb))
                mem[d] += m_a[vs]
                peak[d] = max(peak[d], mem[d])
            if ins.b is not None:
                vs, mb = ins.b
                if (vs, mb) not in acts:
                    raise ScheduleVerificationError(
                        f"double-free: B({vs},{mb}) has no live activation")
                if (vs, mb) in offloaded:
                    raise ScheduleVerificationError(
                        f"offload leak: B({vs},{mb}) consumes an activation "
                        "whose α-slice is still on host (missing FETCH)")
                acts.discard((vs, mb))
                mem[d] -= m_a[vs]
                done.add(("B", vs, mb))
                tapes.add((vs, mb))
            if ins.w is not None:
                if ins.w not in tapes:
                    raise ScheduleVerificationError(
                        f"double-free: W{ins.w} has no live weight tape")
                tapes.discard(ins.w)
                done.add(("W", *ins.w))
            ptr[d] += 1
            remaining -= 1
            progressed = True
        if not progressed:
            heads = [schedule[d][ptr[d]] if ptr[d] < len(schedule[d])
                     else None for d in range(n_dev)]
            raise ScheduleVerificationError(
                f"dependency deadlock; per-device heads: {heads}")
    if tapes or acts:
        raise ScheduleVerificationError(
            f"leak at end of schedule: live tapes {sorted(tapes)[:8]}, "
            f"live activations {sorted(acts)[:8]}")
    if offloaded:
        raise ScheduleVerificationError(
            "offload leak at end of schedule: still on host "
            f"{sorted(offloaded)[:8]}")
    if mem_bound is not None and peak.max() > mem_bound + 1e-9:
        raise ScheduleVerificationError(
            f"peak in-flight activation memory {peak.max():.2f} exceeds "
            f"bound {mem_bound:.2f} (per device: {peak.tolist()})")
    return peak


# ---------------------------------------------------------------------------
# Greedy policy-driven schedule *generation* (used for ZB-V / STP).
# ---------------------------------------------------------------------------

@dataclass
class PolicyState:
    """Per-device view handed to a policy at dispatch time."""
    device: int
    now: float
    ready_f: list                    # [(vs, mb)] deps satisfied (uncapped)
    ready_b: list                    # [(vs, mb)] deps satisfied
    pending_w: list                  # [(vs, mb)] B done, W not yet issued
    inflight: int                    # F issued - B issued on this device
    f_left: int                      # F ops not yet issued on this device
    b_done: int                      # B components completed on this device
    cap_ok: bool = True              # standalone F would respect the cap
    soon_b: list = field(default_factory=list)
    # [(vs, mb, dep_time)] B ops whose upstream finishes in the near future —
    # braidable as the B-part of an F&B block (B units start after F units).


def generate(policy, pl: Placement, t: StageTimes, m: int,
             cap: Optional[int] = None) -> list[list[Instr]]:
    """Run the event engine with ``policy`` choosing each device's next
    instruction; record the chosen per-device tables.

    ``policy(state) -> Instr | None`` — None means stay idle (the device
    waits for the next event even if some op is technically ready).
    """
    n_dev, n_vs = pl.p, pl.n_vs
    my_vs = [[vs for vs in range(n_vs) if pl.device(vs) == d]
             for d in range(n_dev)]
    todo_f = {d: {(vs, mb) for vs in my_vs[d] for mb in range(m)}
              for d in range(n_dev)}
    todo_b = {d: {(vs, mb) for vs in my_vs[d] for mb in range(m)}
              for d in range(n_dev)}
    pending_w = {d: [] for d in range(n_dev)}
    issued_w = {d: set() for d in range(n_dev)}
    inflight = [0] * n_dev
    b_done = [0] * n_dev
    free = np.zeros(n_dev)
    finish: dict = {}
    tables: list[list[Instr]] = [[] for _ in range(n_dev)]
    horizon: list[float] = []        # future completion times

    slack = float(t.t_f.max())

    def ready(d, now):
        rf, rb, sb = [], [], []
        for vs, mb in sorted(todo_f[d], key=lambda x: (x[1], -x[0])):
            if vs == 0:
                rf.append((vs, mb))
                continue
            key = ("F", vs - 1, mb)
            hop = t.t_comm if pl.device(vs - 1) != d else 0.0
            if key in finish and finish[key] + hop <= now:
                rf.append((vs, mb))
        for vs, mb in sorted(todo_b[d], key=lambda x: (x[1], -x[0])):
            if vs == n_vs - 1:
                key = ("F", vs, mb)
                hop = 0.0
            else:
                key = ("B", vs + 1, mb)
                hop = t.t_comm if pl.device(vs + 1) != d else 0.0
            if key in finish:
                dep = finish[key] + hop
                if dep <= now:
                    rb.append((vs, mb))
                elif dep <= now + slack:
                    sb.append((vs, mb, dep))
        return rf, rb, sb

    total_ops = lambda: sum(len(todo_f[d]) + len(todo_b[d])
                            + len(pending_w[d]) for d in range(n_dev))

    guard = 0
    while total_ops():
        guard += 1
        if guard > 100 * n_dev * n_vs * max(m, 1) + 1000:
            raise RuntimeError("generation did not converge")
        progressed = False
        order = sorted(range(n_dev), key=lambda d: free[d])
        now = free[order[0]]
        for d in order:
            if free[d] > now:
                break
            rf, rb, sb = ready(d, now)
            st = PolicyState(device=d, now=now, ready_f=rf, ready_b=rb,
                             pending_w=list(pending_w[d]),
                             inflight=inflight[d], f_left=len(todo_f[d]),
                             b_done=b_done[d],
                             cap_ok=(cap is None or inflight[d] < cap),
                             soon_b=sb)
            instr = policy(st)
            if instr is None:
                continue
            dur, _ = duration(instr, t)
            end = now + dur
            for ph, vs, mb in instr.components():
                finish[(ph, vs, mb)] = end
                if ph == "F":
                    todo_f[d].discard((vs, mb))
                    inflight[d] += 1
                elif ph == "B":
                    todo_b[d].discard((vs, mb))
                    inflight[d] -= 1
                    b_done[d] += 1
                    if instr.kind in ("B", "FB", "BWx"):
                        pending_w[d].append((vs, mb))
                else:
                    if (vs, mb) in pending_w[d]:
                        pending_w[d].remove((vs, mb))
            free[d] = end
            horizon.append(end)
            if t.t_comm:
                horizon.append(end + t.t_comm)   # cross-stage readiness
            tables[d].append(instr)
            progressed = True
        if not progressed:
            future = [x for x in horizon if x > now]
            nxt = [f for f in free if f > now]
            cands = future + nxt
            if not cands:
                raise RuntimeError("generation deadlock")
            adv = min(cands)
            for d in range(n_dev):
                if free[d] <= now:
                    free[d] = adv
    return tables
