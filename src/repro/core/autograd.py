"""Decoupled backward machinery (Zero Bubble-style B/W split).

The paper (§3) decouples each unit's backward pass into

  * **B** — activation-gradient computation (`bwd_act`): propagates the
    upstream gradient to the unit's input so the previous unit / PP stage can
    proceed, and
  * **W** — weight-gradient computation (`bwd_weight`): the large GEMMs
    ``dW = x^T g`` which can be *deferred* to fill pipeline bubbles.

We realize this exactly (no recompute of the big GEMMs) with two pieces:

1. ``linear_*`` — hand-split dense projections.  ``bwd_act`` only multiplies
   by ``W^T``; the ``(x, g)`` pair needed for ``dW`` is recorded on a
   *weight tape* and consumed later by ``bwd_weight``.
2. ``core_vjp`` — everything that is not a big projection (softmax attention,
   RoPE, norms, gating nonlinearities, SSM scans) is treated as a *core*
   function.  Its backward is ``jax.vjp`` with recompute of the (cheap) core
   forward; gradients of the core's *small* parameters (norm gains, scan
   gates, conv kernels — <1% of unit FLOPs) are computed jointly with B.
   This matches production Zero-Bubble implementations, which split only
   ``Linear`` layers.

Everything here is pure-functional and pytree-friendly so tapes can be
carried through ``lax.scan`` / ``lax.switch`` in the pipeline executor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Split linear projection.
#
# All projections in the framework are of the form  y[..., f] = x[..., d] W[d, f]
# (experts add a leading batch dim E handled by vmap-like einsum specs below).
# ---------------------------------------------------------------------------

def linear_fwd(x, w):
    """y = x @ w.  Returns (y, saved_input)."""
    return jnp.einsum("...d,df->...f", x, w), x


def linear_bwd_act(g, w):
    """B-part: dx = g @ w^T.  O(tokens * d * f) but no weight-grad GEMM."""
    return jnp.einsum("...f,df->...d", g, w)


def linear_bwd_weight(x, g):
    """W-part: dW = x^T g, contracted over all leading (token) dims.
    fp32 accumulation via preferred_element_type — no materialized fp32
    copies of the (large) bf16 activations (§Perf: saves ~2x HBM traffic
    on every weight-gradient GEMM vs the astype form)."""
    return jnp.einsum("...d,...f->df", x, g,
                      preferred_element_type=jnp.float32).astype(g.dtype)


def expert_linear_fwd(x, w):
    """Per-expert projection: x (E, C, d), w (E, d, f)."""
    return jnp.einsum("ecd,edf->ecf", x, w), x


def expert_linear_bwd_act(g, w):
    return jnp.einsum("ecf,edf->ecd", g, w)


def expert_linear_bwd_weight(x, g):
    return jnp.einsum("ecd,ecf->edf", x, g,
                      preferred_element_type=jnp.float32).astype(g.dtype)


def head_linear_fwd(x, w):
    """Per-head (block-diagonal) projection: x (b, s, h, d), w (h, d, e).
    This is the TP-shardable form used by the xLSTM mixers (heads shard)."""
    return jnp.einsum("bshd,hde->bshe", x, w), x


def head_linear_bwd_act(g, w):
    return jnp.einsum("bshe,hde->bshd", g, w)


def head_linear_bwd_weight(x, g):
    return jnp.einsum("bshd,bshe->hde", x, g,
                      preferred_element_type=jnp.float32).astype(g.dtype)


# ---------------------------------------------------------------------------
# Weight tape.
#
# A tape entry is just the (x, g) pair for one projection plus a static kind
# tag.  Tapes are plain dicts {param_name: (kind, x, g)} with static keys, so
# they are valid pytrees; `kind` is encoded structurally by which bwd_weight
# function the unit applies (units know their own projections).
# ---------------------------------------------------------------------------

def tape_entry(x, g):
    return (x, g)


def tape_weight(entry, *, expert: bool = False):
    x, g = entry
    return expert_linear_bwd_weight(x, g) if expert else linear_bwd_weight(x, g)


# ---------------------------------------------------------------------------
# Core functions (non-projection math) via vjp-with-recompute.
# ---------------------------------------------------------------------------

def core_vjp(core_fn, core_params, *inputs):
    """Run ``core_fn(core_params, *inputs)`` forward; return (y, saved)
    where ``saved`` holds the *raw inputs* (not the vjp closure, which is not
    a pytree).  ``core_bwd`` below re-runs the forward under ``jax.vjp`` —
    the core is by construction cheap relative to the unit's projections."""
    y = core_fn(core_params, *inputs)
    return y, (core_params, inputs)


def core_bwd(core_fn, saved, gy):
    """Returns (core_param_grads, input_grads_tuple)."""
    core_params, inputs = saved
    _, vjp = jax.vjp(lambda p, *xs: core_fn(p, *xs), core_params, *inputs)
    grads = vjp(gy)
    return grads[0], grads[1:]


# ---------------------------------------------------------------------------
# Norm cores (used standalone by the Pre-Attn / Pre-MLP units).
# ---------------------------------------------------------------------------

def rmsnorm(g, x, eps: float = 1e-6):
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    n = x.astype(jnp.float32) * jax.lax.rsqrt(ms + eps)
    return (n * g.astype(jnp.float32)).astype(x.dtype)


def layernorm(params, x, eps: float = 1e-5):
    g, b = params
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    n = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (n * g.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def l2norm(x, eps: float = 1e-6):
    """Parameter-free L2 norm over the trailing dim (qk-norm variant)."""
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(ms + eps)).astype(x.dtype)
