"""Schedule builders: GPipe, 1F1B, 1F1B-I, ZB-V and the paper's STP.

A schedule is a per-device ordered list of :class:`repro.core.simulator.Instr`
over virtual stages.  Explicit generators are used where the literature fixes
the order (GPipe, 1F1B, Megatron's interleaved 1F1B); the decoupled-backward
schedules (ZB-V, STP and its memory-efficient variant) are produced by
running the event engine with a greedy dispatch *policy* — the recorded
tables are then replayable by :func:`repro.core.simulator.simulate` and
executable by the shard_map pipeline runtime.

The STP policy implements §4.2:
  * warm-up: max feasible in-flight microbatches; decoupled B (weight
    separation ON) everywhere but the last virtual stage, braided as F&B /
    F&W blocks as soon as partners exist;
  * steady: braided F&B with *full* backward (weight separation OFF),
    alternating chunk 1 / chunk 0 (same-chunk pattern (2) of §4.1);
  * degraded (microbatches exhausted): weight separation reactivated —
    braided F&B with deferred W;
  * cool-down: remaining B's braided with stored W's (``BWx``), leftover W's
    fill the tail.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.simulator import (Instr, Placement, PolicyState, StageTimes,
                                  flat, generate, parallel, simulate,
                                  verify_tables, vshape)

SCHEDULES = ("gpipe", "1f1b", "1f1b-i", "zb-v", "stp", "stp-memeff")


def memory_bound(kind: str, p: int, m: int) -> float:
    """Per-device peak in-flight activation bound, in per-virtual-stage
    activation units (Table 1, +1 transient slack for the braided/1F1B F
    that executes before its paired B releases)."""
    bounds = {
        "gpipe": float(m),            # all microbatches resident
        "1f1b": float(p),             # warm-up depth
        "1f1b-i": float(3 * p - 2),   # Megatron interleaved, v=2
        "zb-v": float(2 * p),         # controllable-memory V
        "stp": float(3 * p),          # paper §4.3
        "stp-memeff": float(2 * p),   # App. A/B variant (d)
    }
    return bounds[kind] + 1.0


# ---------------------------------------------------------------------------
# Explicit generators (v = 1).
# ---------------------------------------------------------------------------

def gpipe_schedule(p: int, m: int) -> tuple[list[list[Instr]], Placement]:
    pl = flat(p)
    tables = []
    for d in range(p):
        t = [Instr("F", f=(d, i)) for i in range(m)]
        t += [Instr("BW", b=(d, i), w=(d, i)) for i in range(m)]
        tables.append(t)
    return tables, pl


def f1b1_schedule(p: int, m: int) -> tuple[list[list[Instr]], Placement]:
    """Non-interleaved 1F1B (PipeDream-flush)."""
    pl = flat(p)
    tables = []
    for d in range(p):
        warm = min(p - 1 - d, m)
        t = [Instr("F", f=(d, i)) for i in range(warm)]
        for i in range(m - warm):
            t.append(Instr("F", f=(d, warm + i)))
            t.append(Instr("BW", b=(d, i), w=(d, i)))
        for i in range(m - warm, m):
            t.append(Instr("BW", b=(d, i), w=(d, i)))
        tables.append(t)
    return tables, pl


# ---------------------------------------------------------------------------
# Interleaved 1F1B (Megatron-LM), v = 2, parallel placement.
# ---------------------------------------------------------------------------

def interleaved_schedule(p: int, m: int, v: int = 2
                         ) -> tuple[list[list[Instr]], Placement]:
    if m % p:
        raise ValueError("1F1B-I requires microbatches % p == 0 "
                         f"(got m={m}, p={p})")
    assert v == 2, "paper setting: two virtual stages per device"
    pl = parallel(p)
    total = m * v
    tables = []
    for d in range(p):
        def fwd(n):
            grp = n % (p * v)
            return grp // p, (n // (p * v)) * p + grp % p   # (chunk, mb)

        def bwd(n):
            grp = n % (p * v)
            return v - 1 - grp // p, (n // (p * v)) * p + grp % p

        warm = min((p - d - 1) * 2 + (v - 1) * p, total)
        t = []
        for n in range(warm):
            c, mb = fwd(n)
            t.append(Instr("F", f=(pl.vs_of(d, c), mb)))
        for n in range(total - warm):
            c, mb = fwd(warm + n)
            t.append(Instr("F", f=(pl.vs_of(d, c), mb)))
            c, mb = bwd(n)
            vs = pl.vs_of(d, c)
            t.append(Instr("BW", b=(vs, mb), w=(vs, mb)))
        for n in range(total - warm, total):
            c, mb = bwd(n)
            vs = pl.vs_of(d, c)
            t.append(Instr("BW", b=(vs, mb), w=(vs, mb)))
        tables.append(t)
    return tables, pl


# ---------------------------------------------------------------------------
# Greedy policies (ZB-V, STP).
# ---------------------------------------------------------------------------

def _zbv_policy_factory(n_vs: int):
    def policy(st: PolicyState) -> Optional[Instr]:
        """ZB-V: decoupled backward always; eager B, then F (bounded
        in-flight), W's fill bubbles.  No braiding — every F and B exposes
        its collective.  The loss-stage F is exempt from the cap (its B
        follows immediately; blocking it wedges the whole pipeline)."""
        if st.ready_b:
            vs, mb = st.ready_b[0]
            return Instr("B", b=(vs, mb))
        if st.ready_f:
            if st.cap_ok:
                vs, mb = st.ready_f[0]
                return Instr("F", f=(vs, mb))
            # chunk-1 ("returning") F's are exempt from the in-flight cap:
            # they drain toward the loss stage and unblock the B chain —
            # holding them wedges the V dataflow at large p.
            back = [f for f in st.ready_f if f[0] >= n_vs // 2]
            if back:
                return Instr("F", f=back[0])
        if st.pending_w:
            vs, mb = st.pending_w[0]
            return Instr("W", w=(vs, mb))
        return None

    return policy


def zbv_schedule(p: int, m: int, times: Optional[StageTimes] = None
                 ) -> tuple[list[list[Instr]], Placement]:
    pl = vshape(p)
    t = times or StageTimes.uniform(pl.n_vs)
    tables = generate(_zbv_policy_factory(pl.n_vs), pl, t, m, cap=2 * p)
    return tables, pl


def _stp_policy_factory(p: int, n_vs: int, t: StageTimes):
    """STP (§4.2).  Phases are detected from per-device progress:
    warm-up ≈ first p B's, degraded/cool-down when the F queue runs dry."""
    def braided(f, b, st):
        vs, mb = b
        warmup = st.b_done < p - 1 and vs != n_vs - 1
        degraded = st.f_left <= 2
        if warmup or degraded:
            return Instr("FB", f=f, b=b)                     # W deferred
        return Instr("FBW", f=f, b=b, w=b)

    def policy(st: PolicyState) -> Optional[Instr]:
        if st.ready_b:
            vs, mb = st.ready_b[0]
            # pattern (2): braid with a later-microbatch F of the SAME chunk;
            # fall back to the other chunk's F (pattern (1)) if none.
            braid = [f for f in st.ready_f if f[0] == vs and f[1] > mb] \
                or [f for f in st.ready_f if f[0] != vs]
            if braid:
                return braided(braid[0], (vs, mb), st)
            if st.f_left == 0 and st.pending_w:
                w = st.pending_w[0]
                return Instr("BWx", b=(vs, mb), w=w)         # cool-down
            if st.b_done < p - 1 and vs != n_vs - 1 and st.f_left > 0:
                return Instr("B", b=(vs, mb))                # warm-up W-sep
            return Instr("BW", b=(vs, mb), w=(vs, mb))
        if st.ready_f:
            # braid with an *imminent* B whose upstream gradient lands inside
            # this F's execution window (the B units of the block run after
            # the F units — Fig. 3's interleaving).
            for f in st.ready_f:
                cands = [c for c in st.soon_b
                         if c[2] <= st.now + t.t_f[f[0]]
                         and (c[0], c[1]) != f]
                same = [c for c in cands if c[0] == f[0] and c[1] < f[1]] \
                    or cands
                if same:
                    vs, mb, _ = same[0]
                    return braided(f, (vs, mb), st)
            # self-braid at the loss stage: F(top, i) fused with its own
            # loss backward B(top, i) — the "early backward pass on device
            # 0" of Fig. 4.  Net-zero in-flight, so exempt from the cap.
            tops = [f for f in st.ready_f if f[0] == n_vs - 1]
            if tops and not st.cap_ok:
                return braided(tops[0], tops[0], st)
            # standalone F: warm-up fill (and pipeline progress), capped.
            if st.cap_ok:
                f = st.ready_f[0]
                if st.pending_w:
                    return Instr("FW", f=f, w=st.pending_w[0])  # F&W block
                return Instr("F", f=f)
        if st.pending_w:
            vs, mb = st.pending_w[0]
            return Instr("W", w=(vs, mb))
        return None

    return policy


def stp_schedule(p: int, m: int, times: Optional[StageTimes] = None,
                 mem_efficient: bool = False
                 ) -> tuple[list[list[Instr]], Placement]:
    pl = vshape(p)
    t = times or StageTimes.uniform(pl.n_vs)
    cap = 2 * p if mem_efficient else 3 * p
    tables = generate(_stp_policy_factory(p, pl.n_vs, t), pl, t, m, cap=cap)
    return tables, pl


# ---------------------------------------------------------------------------
# Registry & validation.
# ---------------------------------------------------------------------------

def build(kind: str, p: int, m: int, times: Optional[StageTimes] = None
          ) -> tuple[list[list[Instr]], Placement]:
    if p < 2:
        raise ValueError(
            f"pipeline schedules need p >= 2 stages, got p={p} "
            f"(kind={kind!r}); use the pjit runtime for single-stage runs")
    if kind == "gpipe":
        return gpipe_schedule(p, m)
    if kind == "1f1b":
        return f1b1_schedule(p, m)
    if kind == "1f1b-i":
        return interleaved_schedule(p, m)
    if kind == "zb-v":
        return zbv_schedule(p, m, times)
    if kind == "stp":
        return stp_schedule(p, m, times)
    if kind == "stp-memeff":
        return stp_schedule(p, m, times, mem_efficient=True)
    raise KeyError(f"unknown schedule {kind!r}; known: {SCHEDULES}")


def validate(tables, pl: Placement, m: int) -> None:
    """Structural validity — delegates to the static IR verifier
    (:func:`repro.core.simulator.verify_tables`): uniqueness, ownership and
    completeness are checked statically; ordering violations (W before its
    B, B before its F) surface as replay deadlocks or double-frees."""
    verify_tables(tables, pl, m)


def run(kind: str, p: int, m: int, times: Optional[StageTimes] = None):
    """Build + verify + simulate; the one-call entry point used by
    benchmarks.  The static IR verifier runs before the timed replay so a
    malformed table fails loudly rather than deadlocking mid-simulation.
    The Table-1 memory bound only applies to uniform stage times — the
    greedy generators legitimately hold more in flight when stages are
    imbalanced (e.g. the MLLM ViT-heavy first stage)."""
    tables, pl = build(kind, p, m, times)
    t = times or StageTimes.uniform(pl.n_vs)
    verify_tables(tables, pl, m,
                  mem_bound=memory_bound(kind, p, m) if times is None
                  else None)
    return simulate(tables, pl, t, m), tables, pl
