"""Schedule builders: GPipe, 1F1B, 1F1B-I, ZB-V and the paper's STP.

A schedule is a per-device ordered list of :class:`repro.core.simulator.Instr`
over virtual stages.  Explicit generators are used where the literature fixes
the order (GPipe, 1F1B, Megatron's interleaved 1F1B); the decoupled-backward
schedules (ZB-V, STP and its memory-efficient variant) are produced by
running the event engine with a greedy dispatch *policy* — the recorded
tables are then replayable by :func:`repro.core.simulator.simulate` and
executable by the shard_map pipeline runtime.

The STP policy implements §4.2:
  * warm-up: max feasible in-flight microbatches; decoupled B (weight
    separation ON) everywhere but the last virtual stage, braided as F&B /
    F&W blocks as soon as partners exist;
  * steady: braided F&B with *full* backward (weight separation OFF),
    alternating chunk 1 / chunk 0 (same-chunk pattern (2) of §4.1);
  * degraded (microbatches exhausted): weight separation reactivated —
    braided F&B with deferred W;
  * cool-down: remaining B's braided with stored W's (``BWx``), leftover W's
    fill the tail.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.simulator import (Instr, Placement, PolicyState, StageTimes,
                                  flat, generate, parallel, simulate,
                                  verify_tables, vshape)

SCHEDULES = ("gpipe", "1f1b", "1f1b-i", "zb-v", "stp", "stp-memeff")


# ---------------------------------------------------------------------------
# Layer-to-stage partitioning (contiguous ranges per virtual stage).
# ---------------------------------------------------------------------------

def layer_cost(spec, cfg) -> float:
    """FLOPs-proportional per-layer cost estimate (matmul weight volume —
    the 2*b*s factor is common to every layer and drops out of balancing).
    Rough is fine: only ratios between layer kinds matter."""
    d = cfg.d_model
    c = 0.0
    if spec.mixer == "attn":
        hd = cfg.hd
        c += d * (2 * cfg.n_heads * hd + 2 * cfg.kv_heads * hd)
    elif spec.mixer in ("mamba", "mlstm", "slstm"):
        c += 4 * d * d * cfg.ssm_expand
    if spec.mlp == "gated":
        c += 3 * d * cfg.d_ff
    elif spec.mlp == "plain":
        c += 2 * d * cfg.d_ff
    elif spec.mlp == "moe" and cfg.moe is not None:
        # active-expert FLOPs only (router + top_k expert FFNs per token).
        gates = 3 if cfg.moe.gated else 2
        c += cfg.moe.top_k * gates * d * cfg.moe.d_ff + d * cfg.moe.num_experts
    return c


def uniform_ranges(n: int, n_vs: int) -> tuple[tuple[int, int], ...]:
    """Near-uniform contiguous split ignoring per-layer cost: base+1 layers
    to the first ``n % n_vs`` stages (the paper's 'last stage has fewer
    layers' guidance for the vocab-heavy loss stage).  This is the naive
    baseline the cost-balanced :func:`partition` is measured against.

    Degenerate ``n < n_vs`` yields empty tail stages (supported by the
    reference executor only; the SPMD runtime rejects empty stages)."""
    if n < 1 or n_vs < 1:
        raise ValueError(f"cannot split {n} layers over {n_vs} stages")
    base, rem = divmod(n, n_vs)
    bounds, start = [], 0
    for i in range(n_vs):
        stop = start + base + (1 if i < rem else 0)
        bounds.append((start, stop))
        start = stop
    return tuple(bounds)


def partition(cfg, n_vs: int, *, ranges=None, vit_factor: float = 1.0,
              costs=None) -> tuple[tuple[int, int], ...]:
    """Map ``cfg.layers`` to contiguous per-virtual-stage ``(start, stop)``
    ranges, one per virtual stage in dataflow order.

    ``ranges``      — explicit user-given ranges (validated: contiguous,
                      non-empty, covering all layers) take precedence.
    ``vit_factor``  — multiplier on virtual stage 0's cost, modelling a VLM
                      frontend (ViT encoder) resident on the first stage;
                      mirrors ``StageTimes.scaled_vs(0, vit_factor)``.
    ``costs``       — optional per-layer cost overrides (defaults to
                      :func:`layer_cost` over ``cfg.layers``).

    Cost-balanced mode minimises the bottleneck (max weighted stage cost)
    exactly, then among bottleneck-optimal partitions minimises the sum of
    squared stage costs (balance), preferring heavier *earlier* stages on
    ties — so uniform costs reproduce the near-uniform split of
    :func:`uniform_ranges` exactly.
    """
    n = cfg.n_layers
    if ranges is not None:
        ranges = tuple((int(a), int(b)) for a, b in ranges)
        if len(ranges) != n_vs:
            raise ValueError(f"need {n_vs} ranges, got {len(ranges)}")
        pos = 0
        for i, (a, b) in enumerate(ranges):
            if a != pos or b < a:
                raise ValueError(
                    f"ranges must be contiguous from layer 0: "
                    f"stage {i} got [{a},{b}) at position {pos}")
            pos = b
        if pos != n:
            raise ValueError(f"ranges cover {pos} of {n} layers")
        return ranges
    if n < 1 or n_vs < 1:
        raise ValueError(f"cannot split {n} layers over {n_vs} stages")
    if n < n_vs:
        # Degenerate tiny configs: one layer per early stage, empty tails
        # (legacy near-uniform rule; cost-balancing has no freedom here).
        return uniform_ranges(n, n_vs)
    if costs is None:
        costs = [layer_cost(spec, cfg) for spec in cfg.layers]
    costs = [float(c) for c in costs]
    if len(costs) != n:
        raise ValueError(f"need {n} costs, got {len(costs)}")
    if all(c == 0.0 for c in costs):
        costs = [1.0] * n
    weight = [vit_factor if s == 0 else 1.0 for s in range(n_vs)]
    pre = [0.0]
    for c in costs:
        pre.append(pre[-1] + c)
    seg = lambda a, b: pre[b] - pre[a]          # cost of layers [a, b)

    # Pass 1 — exact bottleneck B*: dp[s][i] = min over partitions of
    # layers[i:] into the last s stages of the max weighted stage cost.
    INF = float("inf")
    dp = [[INF] * (n + 1) for _ in range(n_vs + 1)]
    dp[0][n] = 0.0
    for s in range(1, n_vs + 1):
        w = weight[n_vs - s]
        for i in range(n - s, -1, -1):
            best = INF
            for j in range(i + 1, n - s + 2):
                best = min(best, max(w * seg(i, j), dp[s - 1][j]))
            dp[s][i] = best
    bstar = dp[n_vs][0] * (1 + 1e-12)           # float-tolerant cap

    # Pass 2 — among cap-feasible partitions minimise sum of squared
    # weighted stage costs: sq[s][i] over the same suffix states.
    sq = [[INF] * (n + 1) for _ in range(n_vs + 1)]
    sq[0][n] = 0.0
    for s in range(1, n_vs + 1):
        w = weight[n_vs - s]
        for i in range(n - s, -1, -1):
            best = INF
            for j in range(i + 1, n - s + 2):
                c = w * seg(i, j)
                if c <= bstar and sq[s - 1][j] < INF:
                    best = min(best, c * c + sq[s - 1][j])
            sq[s][i] = best

    # Reconstruct forward, taking the *largest* first segment achieving the
    # optimum at each step (earliest-heavy tie-break).
    bounds, i = [], 0
    for s in range(n_vs, 0, -1):
        w = weight[n_vs - s]
        cands = []
        for j in range(i + 1, n - (s - 1) + 1):
            c = w * seg(i, j)
            if c <= bstar and sq[s - 1][j] < INF:
                cands.append((c * c + sq[s - 1][j], j))
        assert cands, "partition reconstruction failed"
        best = min(t for t, _ in cands)
        tol = 1e-9 * max(1.0, best)
        j = max(j for t, j in cands if t <= best + tol)
        bounds.append((i, j))
        i = j
    assert i == n
    return tuple(bounds)


def memory_bound(kind: str, p: int, m: int,
                 offload_alpha: float = 0.0) -> float:
    """Per-device peak in-flight activation bound, in per-virtual-stage
    activation units (Table 1, +1 transient slack for the braided/1F1B F
    that executes before its paired B releases).

    With ``offload_alpha`` > 0 the bound is offload-aware (§4.4): tables
    annotated by ``simulator.annotate_offload`` hold only ``(1-α)·m_a``
    between an activation's OFFLOAD and FETCH, so at the peak a guaranteed
    per-kind number of chunk-0 activations is α-offloaded — all resident
    microbatches but the newest for the flat kinds, the warm-up depth's
    worth for the v=2 kinds (chunk-1 activations stay resident).  The
    per-kind counts are pinned against the verifier's exact replay across a
    (p, m) sweep in the test suite."""
    bounds = {
        "gpipe": float(m),            # all microbatches resident
        "1f1b": float(p),             # warm-up depth
        "1f1b-i": float(3 * p - 2),   # Megatron interleaved, v=2
        "zb-v": float(2 * p),         # controllable-memory V
        "stp": float(3 * p),          # paper §4.3
        "stp-memeff": float(2 * p),   # App. A/B variant (d)
    }
    offload_units = {
        "gpipe": float(m - 1),
        "1f1b": float(min(p, m) - 1),
        "1f1b-i": float(p),
        "zb-v": float(min(p, m)),
        "stp": float(min(p, m)),
        "stp-memeff": float(min(p, m)),
    }
    return bounds[kind] + 1.0 - offload_alpha * offload_units[kind]


# ---------------------------------------------------------------------------
# Explicit generators (v = 1).
# ---------------------------------------------------------------------------

def gpipe_schedule(p: int, m: int) -> tuple[list[list[Instr]], Placement]:
    pl = flat(p)
    tables = []
    for d in range(p):
        t = [Instr("F", f=(d, i)) for i in range(m)]
        t += [Instr("BW", b=(d, i), w=(d, i)) for i in range(m)]
        tables.append(t)
    return tables, pl


def f1b1_schedule(p: int, m: int) -> tuple[list[list[Instr]], Placement]:
    """Non-interleaved 1F1B (PipeDream-flush)."""
    pl = flat(p)
    tables = []
    for d in range(p):
        warm = min(p - 1 - d, m)
        t = [Instr("F", f=(d, i)) for i in range(warm)]
        for i in range(m - warm):
            t.append(Instr("F", f=(d, warm + i)))
            t.append(Instr("BW", b=(d, i), w=(d, i)))
        for i in range(m - warm, m):
            t.append(Instr("BW", b=(d, i), w=(d, i)))
        tables.append(t)
    return tables, pl


# ---------------------------------------------------------------------------
# Interleaved 1F1B (Megatron-LM), v = 2, parallel placement.
# ---------------------------------------------------------------------------

def interleaved_schedule(p: int, m: int, v: int = 2
                         ) -> tuple[list[list[Instr]], Placement]:
    if m % p:
        raise ValueError("1F1B-I requires microbatches % p == 0 "
                         f"(got m={m}, p={p})")
    assert v == 2, "paper setting: two virtual stages per device"
    pl = parallel(p)
    total = m * v
    tables = []
    for d in range(p):
        def fwd(n):
            grp = n % (p * v)
            return grp // p, (n // (p * v)) * p + grp % p   # (chunk, mb)

        def bwd(n):
            grp = n % (p * v)
            return v - 1 - grp // p, (n // (p * v)) * p + grp % p

        warm = min((p - d - 1) * 2 + (v - 1) * p, total)
        t = []
        for n in range(warm):
            c, mb = fwd(n)
            t.append(Instr("F", f=(pl.vs_of(d, c), mb)))
        for n in range(total - warm):
            c, mb = fwd(warm + n)
            t.append(Instr("F", f=(pl.vs_of(d, c), mb)))
            c, mb = bwd(n)
            vs = pl.vs_of(d, c)
            t.append(Instr("BW", b=(vs, mb), w=(vs, mb)))
        for n in range(total - warm, total):
            c, mb = bwd(n)
            vs = pl.vs_of(d, c)
            t.append(Instr("BW", b=(vs, mb), w=(vs, mb)))
        tables.append(t)
    return tables, pl


# ---------------------------------------------------------------------------
# Greedy policies (ZB-V, STP).
# ---------------------------------------------------------------------------

def _zbv_policy_factory(n_vs: int):
    def policy(st: PolicyState) -> Optional[Instr]:
        """ZB-V: decoupled backward always; eager B, then F (bounded
        in-flight), W's fill bubbles.  No braiding — every F and B exposes
        its collective.  The loss-stage F is exempt from the cap (its B
        follows immediately; blocking it wedges the whole pipeline)."""
        if st.ready_b:
            vs, mb = st.ready_b[0]
            return Instr("B", b=(vs, mb))
        if st.ready_f:
            if st.cap_ok:
                vs, mb = st.ready_f[0]
                return Instr("F", f=(vs, mb))
            # chunk-1 ("returning") F's are exempt from the in-flight cap:
            # they drain toward the loss stage and unblock the B chain —
            # holding them wedges the V dataflow at large p.
            back = [f for f in st.ready_f if f[0] >= n_vs // 2]
            if back:
                return Instr("F", f=back[0])
        if st.pending_w:
            vs, mb = st.pending_w[0]
            return Instr("W", w=(vs, mb))
        return None

    return policy


def zbv_schedule(p: int, m: int, times: Optional[StageTimes] = None
                 ) -> tuple[list[list[Instr]], Placement]:
    pl = vshape(p)
    t = times or StageTimes.uniform(pl.n_vs)
    tables = generate(_zbv_policy_factory(pl.n_vs), pl, t, m, cap=2 * p)
    return tables, pl


def _stp_policy_factory(p: int, n_vs: int, t: StageTimes):
    """STP (§4.2).  Phases are detected from per-device progress:
    warm-up ≈ first p B's, degraded/cool-down when the F queue runs dry."""
    def braided(f, b, st):
        vs, mb = b
        warmup = st.b_done < p - 1 and vs != n_vs - 1
        degraded = st.f_left <= 2
        if warmup or degraded:
            return Instr("FB", f=f, b=b)                     # W deferred
        return Instr("FBW", f=f, b=b, w=b)

    def policy(st: PolicyState) -> Optional[Instr]:
        if st.ready_b:
            vs, mb = st.ready_b[0]
            # pattern (2): braid with a later-microbatch F of the SAME chunk;
            # fall back to the other chunk's F (pattern (1)) if none.
            braid = [f for f in st.ready_f if f[0] == vs and f[1] > mb] \
                or [f for f in st.ready_f if f[0] != vs]
            if braid:
                return braided(braid[0], (vs, mb), st)
            if st.f_left == 0 and st.pending_w:
                w = st.pending_w[0]
                return Instr("BWx", b=(vs, mb), w=w)         # cool-down
            if st.b_done < p - 1 and vs != n_vs - 1 and st.f_left > 0:
                return Instr("B", b=(vs, mb))                # warm-up W-sep
            return Instr("BW", b=(vs, mb), w=(vs, mb))
        if st.ready_f:
            # braid with an *imminent* B whose upstream gradient lands inside
            # this F's execution window (the B units of the block run after
            # the F units — Fig. 3's interleaving).
            for f in st.ready_f:
                cands = [c for c in st.soon_b
                         if c[2] <= st.now + t.t_f[f[0]]
                         and (c[0], c[1]) != f]
                same = [c for c in cands if c[0] == f[0] and c[1] < f[1]] \
                    or cands
                if same:
                    vs, mb, _ = same[0]
                    return braided(f, (vs, mb), st)
            # self-braid at the loss stage: F(top, i) fused with its own
            # loss backward B(top, i) — the "early backward pass on device
            # 0" of Fig. 4.  Net-zero in-flight, so exempt from the cap.
            tops = [f for f in st.ready_f if f[0] == n_vs - 1]
            if tops and not st.cap_ok:
                return braided(tops[0], tops[0], st)
            # standalone F: warm-up fill (and pipeline progress), capped.
            if st.cap_ok:
                f = st.ready_f[0]
                if st.pending_w:
                    return Instr("FW", f=f, w=st.pending_w[0])  # F&W block
                return Instr("F", f=f)
        if st.pending_w:
            vs, mb = st.pending_w[0]
            return Instr("W", w=(vs, mb))
        return None

    return policy


def stp_schedule(p: int, m: int, times: Optional[StageTimes] = None,
                 mem_efficient: bool = False
                 ) -> tuple[list[list[Instr]], Placement]:
    pl = vshape(p)
    t = times or StageTimes.uniform(pl.n_vs)
    cap = 2 * p if mem_efficient else 3 * p
    tables = generate(_stp_policy_factory(p, pl.n_vs, t), pl, t, m, cap=cap)
    return tables, pl


# ---------------------------------------------------------------------------
# Registry & validation.
# ---------------------------------------------------------------------------

def build(kind: str, p: int, m: int, times: Optional[StageTimes] = None
          ) -> tuple[list[list[Instr]], Placement]:
    if p < 2:
        raise ValueError(
            f"pipeline schedules need p >= 2 stages, got p={p} "
            f"(kind={kind!r}); use the pjit runtime for single-stage runs")
    if kind == "gpipe":
        return gpipe_schedule(p, m)
    if kind == "1f1b":
        return f1b1_schedule(p, m)
    if kind == "1f1b-i":
        return interleaved_schedule(p, m)
    if kind == "zb-v":
        return zbv_schedule(p, m, times)
    if kind == "stp":
        return stp_schedule(p, m, times)
    if kind == "stp-memeff":
        return stp_schedule(p, m, times, mem_efficient=True)
    raise KeyError(f"unknown schedule {kind!r}; known: {SCHEDULES}")


def validate(tables, pl: Placement, m: int) -> None:
    """Structural validity — delegates to the static IR verifier
    (:func:`repro.core.simulator.verify_tables`): uniqueness, ownership and
    completeness are checked statically; ordering violations (W before its
    B, B before its F) surface as replay deadlocks or double-frees."""
    verify_tables(tables, pl, m)


def run(kind: str, p: int, m: int, times: Optional[StageTimes] = None):
    """Build + verify + simulate; the one-call entry point used by
    benchmarks.  The static IR verifier runs before the timed replay so a
    malformed table fails loudly rather than deadlocking mid-simulation.
    The Table-1 memory bound only applies to uniform stage times — the
    greedy generators legitimately hold more in flight when stages are
    imbalanced (e.g. the MLLM ViT-heavy first stage)."""
    tables, pl = build(kind, p, m, times)
    t = times or StageTimes.uniform(pl.n_vs)
    verify_tables(tables, pl, m,
                  mem_bound=memory_bound(kind, p, m) if times is None
                  else None)
    return simulate(tables, pl, t, m), tables, pl
