"""Closed-form schedule analysis (paper Table 1).

All quantities are for p pipeline stages, m microbatches (p << m), 2 virtual
stages (chunks) per device, per-chunk forward time ``T_F``, activation- and
weight-gradient times ``T_B``/``T_W``, per-chunk TP all-reduce time ``T_AR``
and per-chunk activation memory ``M_a``.

These are the *targets* the event-driven simulator (``core.simulator``) is
validated against in tests and in ``benchmarks/table1_theory.py``.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class UnitTimes:
    """Per-model-chunk timing/memory constants of Table 1."""
    t_f: float = 2.0      # forward
    t_b: float = 2.0      # activation-gradient backward (B)
    t_w: float = 1.0      # weight-gradient backward (W)
    t_ar: float = 0.5     # TP all-reduce of one chunk (fwd == bwd)
    m_a: float = 1.0      # activation memory of one chunk for one microbatch

    @property
    def t_full_b(self) -> float:
        return self.t_b + self.t_w


@dataclass(frozen=True)
class TheoryRow:
    pp_bubble: float      # idle time per device attributable to PP
    tp_bubble: float      # non-overlapped TP communication time
    peak_act_memory: float


def theory_1f1b_i(p: int, m: int, u: UnitTimes) -> TheoryRow:
    """Interleaved 1F1B with 2 virtual stages (Megatron-LM)."""
    return TheoryRow(
        pp_bubble=(p - 1) * (u.t_f + u.t_ar + u.t_b + u.t_w),
        tp_bubble=2 * m * u.t_ar,
        peak_act_memory=(3 * p - 2) * u.m_a,
    )


def theory_zbv(p: int, m: int, u: UnitTimes) -> TheoryRow:
    """Zero Bubble V (controllable-memory V-shape, full B/W decoupling)."""
    return TheoryRow(
        pp_bubble=(p - 1) * (u.t_f + 2 * u.t_ar + u.t_b - 2 * u.t_w),
        tp_bubble=4 * m * u.t_ar,
        peak_act_memory=2 * p * u.m_a,
    )


def theory_stp(p: int, m: int, u: UnitTimes) -> TheoryRow:
    """Ours (synergistic tensor & pipeline schedule)."""
    return TheoryRow(
        pp_bubble=(p - 1) * (u.t_f + u.t_ar + u.t_b - u.t_w),
        tp_bubble=(2 * p + 1) * u.t_ar,
        peak_act_memory=3 * p * u.m_a,
    )


def theory_gpipe(p: int, m: int, u: UnitTimes) -> TheoryRow:
    """GPipe with the model treated as a single chunk per device (v=1):
    classic (p-1)(F+B) bubble; every F and B exposes its collective (the full
    backward hides the AR under W, so only forward ARs count)."""
    t_f = 2 * u.t_f          # v=1: both chunks' layers in one stage pass
    t_b = 2 * (u.t_b + u.t_w)
    t_ar = 2 * u.t_ar
    return TheoryRow(
        pp_bubble=(p - 1) * (t_f + t_ar + t_b),
        tp_bubble=m * t_ar,
        peak_act_memory=2 * m * u.m_a,
    )


def theory_1f1b(p: int, m: int, u: UnitTimes) -> TheoryRow:
    """Non-interleaved 1F1B (PipeDream-flush), v=1."""
    t_f = 2 * u.t_f
    t_b = 2 * (u.t_b + u.t_w)
    t_ar = 2 * u.t_ar
    return TheoryRow(
        pp_bubble=(p - 1) * (t_f + t_ar + t_b),
        tp_bubble=m * t_ar,
        peak_act_memory=2 * p * u.m_a,
    )


THEORY = {
    "gpipe": theory_gpipe,
    "1f1b": theory_1f1b,
    "1f1b-i": theory_1f1b_i,
    "zb-v": theory_zbv,
    "stp": theory_stp,
}


def ideal_time(p: int, m: int, u: UnitTimes) -> float:
    """Zero-bubble, fully-overlapped iteration time: every device busy with
    m microbatches of compute for both of its chunks."""
    return m * 2 * (u.t_f + u.t_b + u.t_w)


def iteration_time(kind: str, p: int, m: int, u: UnitTimes) -> float:
    """Closed-form iteration time estimate: ideal + PP bubble + TP bubble."""
    row = THEORY[kind](p, m, u)
    return ideal_time(p, m, u) + row.pp_bubble + row.tp_bubble
