"""qwen3-moe-235b-a22b [moe] — Qwen3-MoE: 128 experts, top-8, GQA kv=4,
qk-norm (Qwen3 family), head_dim 128.  [hf:Qwen/Qwen3-30B-A3B]"""
from repro.models.config import LayerSpec, ModelConfig, MoEConfig, uniform_layers

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    d_model=4096,
    n_heads=64,
    kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab=151936,
    layers=uniform_layers(94, LayerSpec(mixer="attn", mlp="moe", qk_norm=True)),
    moe=MoEConfig(num_experts=128, top_k=8, d_ff=1536),
    rope_theta=1e6,
    source="[hf:Qwen/Qwen3-30B-A3B]",
)
