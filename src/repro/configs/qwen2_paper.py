"""The paper's own evaluation models (Table 2): Qwen2 12.1B / 26.3B LLMs and
Qwen2-VL 14.9B / 28.8B MLLMs.  Used by the benchmark harness to reproduce
Figs. 7/8 and Table 3 with architecture-accurate unit-time ratios; the ViT
tower of the MLLMs is the stub frontend (assignment carve-out)."""
from repro.models.config import LayerSpec, ModelConfig, uniform_layers

QWEN2_12B = ModelConfig(
    name="qwen2-12.1b-paper",
    family="dense",
    d_model=5120,
    n_heads=40,
    kv_heads=8,
    d_ff=13824,
    vocab=152064,
    layers=uniform_layers(30, LayerSpec(mixer="attn", mlp="gated")),
    rope_theta=1e6,
    source="[paper Table 2]",
)

QWEN2_26B = ModelConfig(
    name="qwen2-26.3b-paper",
    family="dense",
    d_model=7168,
    n_heads=56,
    kv_heads=8,
    d_ff=20480,
    vocab=152064,
    layers=uniform_layers(46, LayerSpec(mixer="attn", mlp="gated")),
    rope_theta=1e6,
    source="[paper Table 2]",
)

# MLLM language towers (ViT as stub; ViT dims recorded for the simulator's
# per-virtual-stage workload model: 14.9B = 1.7B ViT (32L/16H/2048) + 13.2B
# LM; 28.8B = 5.6B ViT (+26L/4096) + 23.2B LM).
QWEN2_VL_14B = ModelConfig(
    name="qwen2-vl-14.9b-paper",
    family="vlm",
    d_model=5120,
    n_heads=40,
    kv_heads=8,
    d_ff=13824,
    vocab=152064,
    layers=uniform_layers(33, LayerSpec(mixer="attn", mlp="gated")),
    frontend="embed",
    rope_theta=1e6,
    source="[paper Table 2]",
)

QWEN2_VL_28B = ModelConfig(
    name="qwen2-vl-28.8b-paper",
    family="vlm",
    d_model=7168,
    n_heads=56,
    kv_heads=8,
    d_ff=20480,
    vocab=152064,
    layers=uniform_layers(43, LayerSpec(mixer="attn", mlp="gated")),
    frontend="embed",
    rope_theta=1e6,
    source="[paper Table 2]",
)

# ViT tower shapes used by the MLLM workload model (simulator only).
VIT_1_7B = dict(layers=32, heads=16, d_model=2048)
VIT_5_6B = dict(layers=26, heads=16, d_model=4096)
