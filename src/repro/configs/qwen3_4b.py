"""qwen3-4b [dense] — qk-norm, GQA kv=8, head_dim 128.  [hf:Qwen/Qwen3-8B]"""
from repro.models.config import LayerSpec, ModelConfig, uniform_layers

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    d_model=2560,
    n_heads=32,
    kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab=151936,
    layers=uniform_layers(36, LayerSpec(mixer="attn", mlp="gated",
                                        qk_norm=True)),
    rope_theta=1e6,
    source="[hf:Qwen/Qwen3-8B]",
)
