"""stablelm-3b [dense] — MHA (kv == heads), gated SiLU FFN.
[hf:stabilityai/stablelm-2-1_6b]"""
from repro.models.config import LayerSpec, ModelConfig, uniform_layers

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    d_model=2560,
    n_heads=32,
    kv_heads=32,
    d_ff=6912,
    vocab=50304,
    layers=uniform_layers(32, LayerSpec(mixer="attn", mlp="gated")),
    rope_theta=1e4,
    source="[hf:stabilityai/stablelm-2-1_6b]",
)
