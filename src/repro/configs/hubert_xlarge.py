"""hubert-xlarge [audio] — encoder-only transformer (wav2vec2 arch), masked
frame-cluster prediction over 504 k-means targets.

The conv waveform feature extractor + conv positional embedding is a STUB per
the assignment carve-out: `input_specs` provides precomputed frame embeddings
(b, s, d_model).  Encoder-only => non-causal attention, no decode shapes
(noted in DESIGN.md §5).  [arXiv:2106.07447]"""
from repro.models.config import LayerSpec, ModelConfig, uniform_layers

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    d_model=1280,
    n_heads=16,
    kv_heads=16,
    d_ff=5120,
    vocab=504,
    layers=uniform_layers(48, LayerSpec(mixer="attn", mlp="plain")),
    norm="layernorm",
    plain_act="gelu",
    causal=False,
    use_rope=False,
    frontend="embed",
    source="[arXiv:2106.07447]",
)
