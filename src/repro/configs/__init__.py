"""Architecture registry: ``--arch <id>`` resolves here."""
from repro.configs.gemma3_12b import CONFIG as _gemma3
from repro.configs.hubert_xlarge import CONFIG as _hubert
from repro.configs.jamba_1_5_large_398b import CONFIG as _jamba
from repro.configs.llava_next_mistral_7b import CONFIG as _llava
from repro.configs.olmoe_1b_7b import CONFIG as _olmoe
from repro.configs.qwen2_paper import (QWEN2_12B, QWEN2_26B, QWEN2_VL_14B,
                                       QWEN2_VL_28B)
from repro.configs.qwen3_4b import CONFIG as _qwen3_4b
from repro.configs.qwen3_moe_235b_a22b import CONFIG as _qwen3_moe
from repro.configs.stablelm_3b import CONFIG as _stablelm
from repro.configs.starcoder2_15b import CONFIG as _starcoder2
from repro.configs.xlstm_125m import CONFIG as _xlstm
from repro.models.config import ModelConfig

ASSIGNED = {
    c.name: c for c in [
        _olmoe, _qwen3_moe, _starcoder2, _llava, _gemma3, _hubert,
        _stablelm, _xlstm, _jamba, _qwen3_4b,
    ]
}

PAPER = {c.name: c for c in [QWEN2_12B, QWEN2_26B, QWEN2_VL_14B, QWEN2_VL_28B]}

REGISTRY = {**ASSIGNED, **PAPER}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]
