"""gemma3-12b [dense] — 5:1 local(1024-window):global attention interleave,
GeGLU, 128k context, 262k vocab, head_dim 256.  [hf:google/gemma-3-1b-pt]"""
from repro.models.config import LayerSpec, ModelConfig, pattern_layers

_LOCAL = LayerSpec(mixer="attn", mlp="gated", window=1024)
_GLOBAL = LayerSpec(mixer="attn", mlp="gated", window=None)

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    d_model=3840,
    n_heads=16,
    kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    layers=pattern_layers(48, [_LOCAL] * 5 + [_GLOBAL]),
    gated_act="gelu",
    rope_theta=1e6,
    max_seq=131072,
    source="[hf:google/gemma-3-1b-pt]",
)
