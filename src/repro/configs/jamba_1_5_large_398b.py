"""jamba-1.5-large-398b [hybrid] — Mamba:attention 7:1 interleave (attention
at position 4 of each 8-layer period), MoE (16 experts top-2) on every other
layer, dense gated FFN otherwise.  Mamba states + sparse KV => sub-quadratic,
runs long_500k.  [arXiv:2403.19887]"""
from repro.models.config import LayerSpec, ModelConfig, MoEConfig, pattern_layers

_PERIOD = [
    LayerSpec(mixer="attn" if i == 4 else "mamba",
              mlp="moe" if i % 2 == 1 else "gated")
    for i in range(8)
]

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    d_model=8192,
    n_heads=64,
    kv_heads=8,
    d_ff=24576,
    vocab=65536,
    layers=pattern_layers(72, _PERIOD),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=24576),
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    rope_theta=1e6,
    source="[arXiv:2403.19887]",
)
