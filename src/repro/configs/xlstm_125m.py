"""xlstm-125m [ssm] — alternating sLSTM / mLSTM blocks (xLSTM[1:1]), 4 heads,
no separate FFN (d_ff=0; the blocks carry their own up/down projections).
Recurrent state => O(1) decode, runs long_500k.  [arXiv:2405.04517]"""
from repro.models.config import LayerSpec, ModelConfig, pattern_layers

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    d_model=768,
    n_heads=4,
    kv_heads=4,
    d_ff=0,
    vocab=50304,
    layers=pattern_layers(12, [LayerSpec(mixer="slstm", mlp="none"),
                               LayerSpec(mixer="mlstm", mlp="none")]),
    use_rope=False,
    source="[arXiv:2405.04517]",
)
