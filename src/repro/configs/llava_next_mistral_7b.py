"""llava-next-mistral-7b [vlm] — Mistral-7B language backbone consuming
projected anyres image-tile patch embeddings + text embeddings.

The vision tower (CLIP ViT-L/14 + 2-layer MLP projector, anyres tiling into
up to 4 tiles + base) is a STUB per the assignment carve-out: `input_specs`
provides the precomputed multimodal embedding sequence (b, s, d_model); a
trainable projector linear is retained in-model.  Text decode uses the token
embedding table.  [hf:llava-hf/llava-v1.6-mistral-7b-hf]"""
from repro.models.config import LayerSpec, ModelConfig, uniform_layers

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    d_model=4096,
    n_heads=32,
    kv_heads=8,
    d_ff=14336,
    vocab=32000,
    layers=uniform_layers(32, LayerSpec(mixer="attn", mlp="gated")),
    frontend="embed",
    rope_theta=1e6,
    source="[hf:llava-hf/llava-v1.6-mistral-7b-hf]",
)
