"""olmoe-1b-7b [moe] — OLMoE: 64 experts, top-8, no dense FFN.
[arXiv:2409.02060]"""
from repro.models.config import LayerSpec, ModelConfig, MoEConfig, uniform_layers

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    d_model=2048,
    n_heads=16,
    kv_heads=16,
    d_ff=0,
    vocab=50304,
    layers=uniform_layers(16, LayerSpec(mixer="attn", mlp="moe")),
    moe=MoEConfig(num_experts=64, top_k=8, d_ff=1024),
    rope_theta=1e4,
    source="[arXiv:2409.02060]",
)
