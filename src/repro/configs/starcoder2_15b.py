"""starcoder2-15b [dense] — GQA kv=4, RoPE, plain (non-gated) GELU FFN,
LayerNorm.  [arXiv:2402.19173]"""
from repro.models.config import LayerSpec, ModelConfig, uniform_layers

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    d_model=6144,
    n_heads=48,
    kv_heads=4,
    d_ff=24576,
    vocab=49152,
    layers=uniform_layers(40, LayerSpec(mixer="attn", mlp="plain")),
    norm="layernorm",
    plain_act="gelu",
    rope_theta=1e5,
    source="[arXiv:2402.19173]",
)
