"""Runtime-portable training state.

``TrainState`` is a pytree holding layout-resident params + AdamW moments +
step.  Three layouts cover the three runtimes:

  canonical — per-layer ``blocks`` list (reference executor, host AdamW)
  period    — period-stacked blocks for the pjit scan path
  stage     — per-(device, chunk) stacked ``{"c0","c1","embed","head"}``
              dict for the shard_map SPMD runtime (mesh-resident)

``from_canonical`` / ``to_canonical`` are the only stack/unstack points in
the training stack; they convert params *and* moments together so optimizer
state survives layout changes.  Checkpoints are always written in canonical
layout (``save_state`` / ``load_state``), so any runtime resumes any other
runtime's checkpoint — including step count and AdamW moments.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw_init
from repro.pipeline.spmd import stack_stages, unstack_stages


@dataclass(frozen=True)
class Layout:
    """Static (hashable) description of how params/moments are arranged."""
    kind: str = "canonical"        # canonical | period | stage
    n_layers: int = 0
    period: int = 1                # period layout: scan period
    p: int = 1                     # stage layout: pipeline depth
    lvs: int = 1                   # stage layout: layers per virtual stage
    placement: str = "vshape"      # stage layout: flat | parallel | vshape
    # stage layout: optional per-virtual-stage ((start, stop), ...) layer
    # ranges for heterogeneous partitions; None means the uniform ``lvs``.
    bounds: Optional[tuple] = None

    @property
    def part(self):
        """The partition argument ``stack/unstack_stages`` expects."""
        return self.lvs if self.bounds is None else self.bounds


def _stack_tree(tree, layout: Layout):
    """Canonical params-shaped tree {'embed','blocks','head'} -> layout."""
    if layout.kind == "canonical":
        return tree
    if layout.kind == "period":
        return {"embed": tree["embed"],
                "blocks": M.stack_blocks(tree["blocks"], layout.period),
                "head": tree["head"]}
    c0, c1 = stack_stages(tree["blocks"], layout.p, layout.part,
                          layout.placement)
    return {"c0": c0, "c1": c1, "embed": tree["embed"],
            "head": tree["head"]}


def _unstack_tree(tree, layout: Layout):
    """Inverse of ``_stack_tree`` (device arrays are fetched to host)."""
    if layout.kind == "canonical":
        return tree
    tree = jax.device_get(tree)
    if layout.kind == "period":
        return {"embed": tree["embed"],
                "blocks": M.unstack_blocks(tree["blocks"], layout.period),
                "head": tree["head"]}
    blocks = unstack_stages(tree["c0"], tree["c1"], layout.n_layers,
                            layout.p, layout.part, layout.placement)
    return {"embed": tree["embed"], "blocks": blocks, "head": tree["head"]}


def decay_mask(params, layout: Layout):
    """Weight-decay eligibility per leaf: canonical rank >= 2, i.e. the
    layout's stacking dims (1 for period blocks, 2 for stage chunks) do not
    promote biases/norm gains into decayed matrices."""
    rank = lambda lead: (lambda x: x.ndim - lead >= 2)
    if layout.kind == "canonical":
        return jax.tree.map(rank(0), params)
    if layout.kind == "period":
        return {"embed": jax.tree.map(rank(0), params["embed"]),
                "blocks": jax.tree.map(rank(1), params["blocks"]),
                "head": jax.tree.map(rank(0), params["head"])}
    return {"c0": jax.tree.map(rank(2), params["c0"]),
            "c1": jax.tree.map(rank(2), params["c1"]),
            "embed": jax.tree.map(rank(0), params["embed"]),
            "head": jax.tree.map(rank(0), params["head"])}


@jax.tree_util.register_pytree_node_class
@dataclass
class TrainState:
    """Layout-resident params + AdamW state; a jit-able pytree whose static
    aux data is the :class:`Layout`."""
    params: Any
    opt: Any                       # {"mu", "nu", "step"} mirroring params
    layout: Layout

    def tree_flatten(self):
        return (self.params, self.opt), self.layout

    @classmethod
    def tree_unflatten(cls, layout, children):
        return cls(children[0], children[1], layout)

    @property
    def step(self):
        return self.opt["step"]

    @classmethod
    def from_canonical(cls, params, layout: Layout, opt=None) -> "TrainState":
        """Build from canonical params (+ optional canonical AdamW state —
        fresh moments otherwise), converting both into ``layout``."""
        opt = adamw_init(params) if opt is None else opt
        return cls(params=_stack_tree(params, layout),
                   opt={"mu": _stack_tree(opt["mu"], layout),
                        "nu": _stack_tree(opt["nu"], layout),
                        "step": jnp.asarray(opt["step"], jnp.int32)},
                   layout=layout)

    def to_canonical(self):
        """-> (params, opt) in canonical layout (host-side)."""
        params = _unstack_tree(self.params, self.layout)
        opt = {"mu": _unstack_tree(self.opt["mu"], self.layout),
               "nu": _unstack_tree(self.opt["nu"], self.layout),
               "step": jax.device_get(self.opt["step"])}
        return params, opt


# ---------------------------------------------------------------------------
# Canonical-layout checkpointing: one on-disk format for all runtimes.
# ---------------------------------------------------------------------------

def save_state(directory, state: TrainState, *, extra: Optional[dict] = None):
    """Checkpoint ``state`` in canonical layout (runtime-portable)."""
    params, opt = state.to_canonical()
    save_checkpoint(directory, (params, opt), step=int(opt["step"]),
                    extra=extra or {})


def load_canonical(directory, cfg: ModelConfig
                   ) -> tuple[Any, Any, int, dict]:
    """Read a canonical checkpoint; returns (params, opt, step, extra).
    Resuming a runtime should hand these to ``runner.init_state(params,
    opt=opt)`` so runner-specific placement (e.g. ``SpmdRunner``'s mesh
    ``device_put``) happens on resume exactly as on a fresh start."""
    like = jax.eval_shape(
        lambda k: (lambda p: (p, adamw_init(p)))(M.init_params(k, cfg)),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    (params, opt), step, extra = load_checkpoint(directory, like)
    return params, opt, step, extra


def load_state(directory, cfg: ModelConfig, layout: Layout
               ) -> tuple[TrainState, int, dict]:
    """Restore a canonical checkpoint into ``layout``; returns
    (state, step, extra).  Step and AdamW moments round-trip for every
    runtime."""
    params, opt, step, extra = load_canonical(directory, cfg)
    return TrainState.from_canonical(params, layout, opt=opt), step, extra
