"""pjit sharding rules for the production mesh.

Megatron-style tensor parallelism on the ``model`` axis (column/row parallel
projections, vocab-parallel embedding + head), batch over (``pod``,)
``data``.  Every rule checks divisibility and falls back to replication —
e.g. hubert's 504-way vocab or xlstm's 4 heads cannot shard 16 ways; the
roofline table then shows the cost and the perf loop decides what to do.

Options (used by the §Perf hillclimb):
  * ``expert_axis``: shard MoE expert dim E on ``model`` instead of the
    expert FFN dim (expert parallelism),
  * ``zero_data``: additionally shard the largest param dim over ``data``
    (ZeRO-3-style; XLA inserts the all-gathers),
  * ``seq_shard``: shard the sequence dim of activations over ``model``
    (sequence parallelism for the norm/residual segments).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShardOptions:
    model_axis: str = "model"
    data_axes: tuple = ("data",)          # ("pod", "data") for multi-pod
    expert_parallel: bool = False
    zero_data: bool = False
    seq_shard: bool = False


# param-name -> candidate shard axis (negative, from the right), in
# preference order; first divisible wins.
_COL = {"wg", "wu", "w1", "w_in_x", "w_in_z", "w_upx", "w_upz", "w_lm"}
_ROW = {"wo", "wd", "w2", "w_out", "w_down"}


def _axes_for(name: str, ndim: int, opts: ShardOptions, cfg: ModelConfig):
    if opts.expert_parallel and name in ("wg", "wu", "wd") and ndim >= 3:
        return [-3]                        # (E, d, f): shard experts
    if name in ("wq", "wk", "wv"):
        return [-3, -1] if ndim >= 3 else [-1]
    if name in ("wi", "wf"):
        return [-2]
    if name in _COL:
        return [-1]
    if name in _ROW:
        return [-2]
    if name == "emb":
        return [-2]                        # vocab-parallel embedding
    return []


def param_specs(tree, mesh: Mesh, cfg: ModelConfig,
                opts: ShardOptions = ShardOptions()):
    """PartitionSpec tree for a (stacked or unstacked) param pytree."""
    msize = mesh.shape[opts.model_axis]
    dsize = 1
    for a in opts.data_axes:
        dsize *= mesh.shape[a]

    def one(path, leaf):
        name = None
        for k in reversed(path):
            if hasattr(k, "key"):
                name = k.key
                break
        spec = [None] * leaf.ndim
        for ax in _axes_for(name, leaf.ndim, opts, cfg):
            i = leaf.ndim + ax
            if 0 <= i < leaf.ndim and leaf.shape[i] % msize == 0 \
                    and spec[i] is None:
                spec[i] = opts.model_axis
                break
        if opts.zero_data:
            # ZeRO-3: shard the largest unsharded dim over data
            order = sorted(range(leaf.ndim), key=lambda i: -leaf.shape[i])
            for i in order:
                if spec[i] is None and leaf.shape[i] % dsize == 0 \
                        and leaf.shape[i] >= dsize:
                    spec[i] = opts.data_axes if len(opts.data_axes) > 1 \
                        else opts.data_axes[0]
                    break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, tree)


def batch_specs(batch_tree, opts: ShardOptions = ShardOptions(),
                mesh: Optional[Mesh] = None):
    """Shard the global batch dim over (pod, data) when divisible (a
    long-context decode batch of 1 stays replicated)."""
    ax = opts.data_axes if len(opts.data_axes) > 1 else opts.data_axes[0]
    dsize = 1
    if mesh is not None:
        for a in opts.data_axes:
            dsize *= mesh.shape[a]

    def one(leaf):
        spec = [None] * leaf.ndim
        if leaf.shape[0] % max(dsize, 1) == 0:
            spec[0] = ax
        return P(*spec)

    return jax.tree_util.tree_map(one, batch_tree)


def cache_specs(cache_tree, cfg: ModelConfig, batch: int, mesh: Mesh,
                opts: ShardOptions = ShardOptions()):
    """Decode KV/state cache sharding: batch over data axes when divisible,
    the KV slot (time) dim over ``model`` — single-query attention over a
    slot-sharded cache becomes distributed flash-decode under GSPMD."""
    msize = mesh.shape[opts.model_axis]
    dsize = 1
    for a in opts.data_axes:
        dsize *= mesh.shape[a]
    dax = opts.data_axes if len(opts.data_axes) > 1 else opts.data_axes[0]

    def one(path, leaf):
        name = None
        for k in reversed(path):
            if hasattr(k, "key"):
                name = k.key
                break
        spec = [None] * leaf.ndim
        if name == "pos":                          # (slots,) bookkeeping
            return P(*spec)
        # leading dims may include period-stack (reps,); batch dim is the
        # first dim equal to `batch`.
        bdim = None
        if batch % dsize == 0 and batch >= dsize:
            bdim = next((i for i, s in enumerate(leaf.shape)
                         if s == batch), None)
            if bdim is not None:
                spec[bdim] = dax
        if name in ("k", "v") and leaf.ndim >= 2:
            t = leaf.ndim - 2                      # (..., slots, hd)
            if t != bdim and leaf.shape[t] % msize == 0:
                spec[t] = opts.model_axis
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def to_named(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
