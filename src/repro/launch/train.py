"""Training driver.

Two runtimes share the model/optimizer/data substrates:

  * ``pjit``     — data(+tensor)-parallel jit train_step (the dry-run's
                   step, executed for real at reduced scale on CPU).
  * ``pipeline`` — any of the six schedules through the single-process
                   reference executor (numerics oracle; one device).
  * ``spmd``     — any of the six schedules through the shard_map runtime
                   on a real (stage[, model]) mesh; needs pp * tp devices
                   (use XLA_FLAGS=--xla_force_host_platform_device_count=N
                   for fake CPU devices).

Usage (CPU example scale):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
      --steps 50 --runtime pjit --seq 128 --batch 8
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
      --runtime pipeline --schedule stp --pp 2 --microbatches 4
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
      --runtime spmd --schedule 1f1b --pp 4 --microbatches 4
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.core.schedule import SCHEDULES, build as build_schedule
from repro.data import DataConfig, make_batches, microbatches
from repro.models import model as M
from repro.optim import OptConfig, adamw_init, adamw_update
from repro.pipeline.reference import pipeline_grads


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--runtime", choices=("pjit", "pipeline", "spmd"),
                    default="pjit")
    ap.add_argument("--schedule", default="stp", choices=SCHEDULES)
    ap.add_argument("--pp", type=int, default=2)
    ap.add_argument("--tp", type=int, default=1,
                    help="model-axis size for the spmd runtime")
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced(n_layers=args.layers, d_model=args.d_model,
                          n_heads=4, vocab=512)
    oc = OptConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                   total_steps=args.steps)
    dc = DataConfig(seq_len=args.seq, global_batch=args.batch,
                    microbatches=args.microbatches)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    opt_state = adamw_init(params)
    start = 0
    if args.ckpt and Path(args.ckpt, "meta.json").exists():
        (params, opt_state), start, _ = load_checkpoint(
            args.ckpt, (params, opt_state))
        print(f"resumed from {args.ckpt} @ step {start}")

    if args.runtime == "pjit":
        period = M.period_of(cfg)

        @jax.jit
        def step_fn(params_s, opt_s, batch):
            loss, grads = jax.value_and_grad(
                lambda p: M.loss_fn(p, batch, cfg))(params_s)
            p2, o2, gn = adamw_update(params_s, grads, opt_s, oc)
            return p2, o2, loss, gn

        params_s = {"embed": params["embed"],
                    "blocks": M.stack_blocks(params["blocks"], period),
                    "head": params["head"]}
        opt_s = adamw_init(params_s)
        t0 = time.time()
        for i, batch in enumerate(make_batches(cfg, dc, args.steps)):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params_s, opt_s, loss, gn = step_fn(params_s, opt_s, batch)
            if (i + start) % args.log_every == 0:
                tok_s = dc.global_batch * dc.seq_len * (i + 1) \
                    / max(time.time() - t0, 1e-9)
                print(f"step {i + start:5d} loss {float(loss):.4f} "
                      f"gnorm {float(gn):.3f} tok/s {tok_s:,.0f}",
                      flush=True)
        params = {"embed": params_s["embed"],
                  "blocks": M.unstack_blocks(params_s["blocks"], period),
                  "head": params_s["head"]}
        opt_state = opt_s
    elif args.runtime == "spmd":
        from jax.sharding import Mesh
        from repro.launch.steps import make_pipeline_grads_fn

        ndev = len(jax.devices())
        if args.pp * args.tp != ndev:
            raise SystemExit(
                f"spmd runtime needs pp*tp == device count "
                f"(pp={args.pp}, tp={args.tp}, devices={ndev}); set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count=N")
        mesh = Mesh(np.array(jax.devices()).reshape(args.pp, args.tp),
                    ("stage", "model"))
        mbb = dc.global_batch // args.microbatches
        grads_fn, pl = make_pipeline_grads_fn(
            cfg, args.schedule, args.pp, args.microbatches,
            (mbb, dc.seq_len), mesh, params,
            model_axis="model" if args.tp > 1 else None)
        t0 = time.time()
        for i, batch in enumerate(make_batches(cfg, dc, args.steps)):
            mbs = microbatches({k: jnp.asarray(v) for k, v in batch.items()},
                               args.microbatches)
            tokens = jnp.stack([b["tokens" if cfg.frontend == "text"
                                  else "embeds"] for b in mbs])
            labels = jnp.stack([b["labels"] for b in mbs])
            loss, grads = grads_fn(params, tokens, labels)
            params, opt_state, gn = adamw_update(params, grads, opt_state,
                                                 oc)
            if (i + start) % args.log_every == 0:
                tok_s = dc.global_batch * dc.seq_len * (i + 1) \
                    / max(time.time() - t0, 1e-9)
                print(f"step {i + start:5d} loss {float(loss):.4f} "
                      f"gnorm {float(gn):.3f} tok/s {tok_s:,.0f} "
                      f"[spmd {args.schedule} {pl.kind} p={args.pp} "
                      f"tp={args.tp} m={args.microbatches}]", flush=True)
    else:
        tables, pl = build_schedule(args.schedule, args.pp,
                                    args.microbatches)
        t0 = time.time()
        for i, batch in enumerate(make_batches(cfg, dc, args.steps)):
            mbs = microbatches({k: jnp.asarray(v) for k, v in batch.items()},
                               args.microbatches)
            loss, grads = pipeline_grads(params, mbs, tables, pl, cfg)
            params, opt_state, gn = adamw_update(params, grads, opt_state,
                                                 oc)
            if (i + start) % args.log_every == 0:
                tok_s = dc.global_batch * dc.seq_len * (i + 1) \
                    / max(time.time() - t0, 1e-9)
                print(f"step {i + start:5d} loss {float(loss):.4f} "
                      f"gnorm {float(gn):.3f} tok/s {tok_s:,.0f} "
                      f"[{args.schedule} p={args.pp} m={args.microbatches}]",
                      flush=True)

    if args.ckpt:
        save_checkpoint(args.ckpt, (params, opt_state),
                        step=start + args.steps,
                        extra={"arch": cfg.name})
        print(f"saved checkpoint to {args.ckpt}")


if __name__ == "__main__":
    main()
