"""Training driver: one loop over the ``Runner`` API for all runtimes.

  * ``pjit``     — data(+tensor)-parallel jit train_step (the dry-run's
                   step, executed for real at reduced scale on CPU).
  * ``pipeline`` — any of the six schedules through the single-process
                   reference executor (numerics oracle; one device).
  * ``spmd``     — any of the six schedules through the shard_map runtime
                   on a real (stage[, model]) mesh with in-mesh AdamW;
                   needs pp * tp devices (use
                   XLA_FLAGS=--xla_force_host_platform_device_count=N for
                   fake CPU devices).

Checkpoints (``--ckpt``) are canonical-layout and runtime-portable: a run
saved under one runtime resumes under any other, including optimizer
moments and step.

Usage (CPU example scale):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
      --steps 50 --runtime pjit --seq 128 --batch 8
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
      --runtime pipeline --schedule stp --pp 2 --microbatches 4
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
      --runtime spmd --schedule 1f1b --pp 4 --microbatches 4
"""
from __future__ import annotations

import argparse
import itertools
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.schedule import SCHEDULES
from repro.data import DataConfig, make_batches
from repro.launch.runner import make_runner
from repro.launch.state import load_canonical, save_state
from repro.models import model as M
from repro.optim import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--runtime", choices=("pjit", "pipeline", "spmd"),
                    default="pjit")
    ap.add_argument("--schedule", default="stp", choices=SCHEDULES)
    ap.add_argument("--pp", type=int, default=2)
    ap.add_argument("--tp", type=int, default=1,
                    help="model-axis size for the spmd runtime")
    ap.add_argument("--ep", type=int, default=1,
                    help="expert-axis size for the spmd runtime (MoE "
                         "archs; needs pp*ep*tp devices)")
    ap.add_argument("--part", default=None,
                    help="explicit per-virtual-stage layer counts, e.g. "
                         "'1,3,3,3' (default: cost-balanced partition)")
    ap.add_argument("--vit-factor", type=float, default=1.0,
                    help="cost multiplier on virtual stage 0 for the "
                         "cost-balanced partition (VLM frontend)")
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--braid-tp", action="store_true",
                    help="spmd only: run composite F&B slots through the "
                         "braided overlap-aware chunk executor")
    ap.add_argument("--offload-alpha", type=float, default=0.0,
                    help="spmd only: §4.4 activation offload — fraction of "
                         "each chunk-0 activation context held in host "
                         "memory between its F and B (0 disables)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced(n_layers=args.layers, d_model=args.d_model,
                          n_heads=4, vocab=512)
    oc = OptConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                   total_steps=args.steps)
    dc = DataConfig(seq_len=args.seq, global_batch=args.batch,
                    microbatches=args.microbatches)

    part = None
    if args.part:
        sizes = [int(x) for x in args.part.split(",")]
        bounds, start_l = [], 0
        for n in sizes:
            bounds.append((start_l, start_l + n))
            start_l += n
        part = tuple(bounds)
    runner = make_runner(args.runtime, cfg, oc, dc, schedule=args.schedule,
                         pp=args.pp, tp=args.tp, ep=args.ep,
                         braid_tp=args.braid_tp, part=part,
                         vit_factor=args.vit_factor,
                         offload_alpha=args.offload_alpha)
    start = 0
    if args.ckpt and Path(args.ckpt, "meta.json").exists():
        params, opt, start, _ = load_canonical(args.ckpt, cfg)
        state = runner.init_state(params, opt=opt)
        print(f"resumed from {args.ckpt} @ step {start}")
    else:
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        state = runner.init_state(params)

    # make_batches is deterministic in dc.seed: fast-forward past the
    # already-trained prefix so a resumed run continues the data stream
    # instead of replaying it.
    stream = itertools.islice(make_batches(cfg, dc, start + args.steps),
                              start, None)
    t0 = time.time()
    for i, batch in enumerate(stream):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = runner.step(state, batch)
        if (i + start) % args.log_every == 0:
            tok_s = dc.global_batch * dc.seq_len * (i + 1) \
                / max(time.time() - t0, 1e-9)
            print(f"step {i + start:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['gnorm']):.3f} tok/s {tok_s:,.0f} "
                  f"[{runner.describe}]", flush=True)

    if args.ckpt:
        save_state(args.ckpt, state, extra={"arch": cfg.name})
        print(f"saved checkpoint to {args.ckpt} @ step {int(state.step)}")


if __name__ == "__main__":
    main()
