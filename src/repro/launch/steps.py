"""Lowerable step functions + ShapeDtypeStruct input specs.

These are what the dry-run lowers and what train.py / serve.py execute:

  * ``train_step``   — loss + grads (mixed precision: bf16 compute, fp32
                       master params) + AdamW update, remat'd layer scan.
  * ``prefill_step`` — forward + last-token logits.
  * ``serve_step``   — ONE new token against a seq_len KV/state cache.

INPUT SHAPES (assignment):
  train_4k       seq  4,096   global_batch 256   train_step
  prefill_32k    seq 32,768   global_batch  32   prefill_step
  decode_32k     seq 32,768   global_batch 128   serve_step
  long_500k      seq 524,288  global_batch   1   serve_step (sub-quadratic)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.schedule import build as build_schedule, memory_bound
from repro.core.simulator import verify_tables
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim.adamw import OptConfig, adamw_update
from repro.tp.context import TPContext

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}

# long_500k needs a sub-quadratic (bounded-memory) attention path:
# SSM/hybrid state archs and the sliding-window dense arch qualify;
# encoder-only hubert has no decode at all.  (DESIGN.md §5.)
LONG_OK_FAMILIES = ("ssm", "hybrid")
LONG_OK_ARCHS = ("gemma3-12b",)           # 5:1 sliding-window locals


def shape_supported(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    kind = SHAPES[shape]["kind"]
    if kind == "decode" and not cfg.causal:
        return False, "encoder-only architecture: no autoregressive decode"
    if shape == "long_500k":
        if cfg.family in LONG_OK_FAMILIES or cfg.name in LONG_OK_ARCHS:
            return True, ""
        return False, "pure full-attention arch: quadratic-free path absent"
    return True, ""


def gemma_long_variant(cfg: ModelConfig) -> ModelConfig:
    """long_500k variant of gemma3: global layers capped at the trained
    context window so the ring-buffer cache stays bounded."""
    layers = tuple(dataclasses.replace(l, window=l.window or cfg.max_seq)
                   for l in cfg.layers)
    return dataclasses.replace(cfg, layers=layers)


# ---------------------------------------------------------------------------
# Step builders (stacked-params layout, pjit path).
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, oc: OptConfig = OptConfig(), *,
                    remat: bool = True, compute_dtype=jnp.bfloat16,
                    tp: TPContext = TPContext()):
    def train_step(params, opt_state, batch):
        def loss_of(p):
            pc = jax.tree.map(lambda x: x.astype(compute_dtype)
                              if x.ndim >= 2 else x, p)
            return M.loss_fn(pc, batch, cfg, remat=remat, tp=tp)

        loss, grads = jax.value_and_grad(loss_of)(params)
        params2, opt_state2, gnorm = adamw_update(params, grads, opt_state,
                                                  oc)
        return params2, opt_state2, loss, gnorm

    return train_step


def make_prefill_step(cfg: ModelConfig, *, compute_dtype=jnp.bfloat16,
                      tp: TPContext = TPContext()):
    def prefill(params, batch):
        pc = jax.tree.map(lambda x: x.astype(compute_dtype)
                          if x.ndim >= 2 else x, params)
        return M.prefill_step(pc, batch, cfg, tp=tp)

    return prefill


def make_serve_step(cfg: ModelConfig, *, compute_dtype=jnp.bfloat16,
                    tp: TPContext = TPContext()):
    def serve(params, caches, batch, pos):
        pc = jax.tree.map(lambda x: x.astype(compute_dtype)
                          if x.ndim >= 2 else x, params)
        return M.decode_step(pc, caches, batch, pos, cfg, tp=tp)

    return serve


def make_pipeline_grads_fn(cfg: ModelConfig, kind: str, p: int, m: int,
                           mb_shape, mesh, params, *,
                           model_axis: Optional[str] = None):
    """Lower schedule ``kind`` through the full pipeline stack — table ->
    verified instruction IR -> slot grid -> shard_map runtime — and return
    ``grads_fn(params, tokens, labels) -> (loss, grads)`` operating on
    *canonical* (unstacked) params/grads, ready for ``adamw_update``.

    This is the grads-only access path kept for the differential tests and
    ad-hoc analysis: it re-stacks params host-side on every call.  Training
    should go through ``repro.api.SpmdRunner``, whose fused step keeps
    stacked params + AdamW moments mesh-resident across steps.

    Any of the six ``repro.core.schedule.SCHEDULES`` works; ``mesh`` must
    carry a ``stage`` axis of size ``p`` (plus ``model_axis`` for TP).
    ``tokens``/``labels`` are the stacked microbatches, shape
    (m, mb_batch[, seq...]).
    """
    from repro.pipeline.spmd import (build_pipeline_step, stack_stage_params,
                                     unstack_stage_grads)

    tables, pl = build_schedule(kind, p, m)
    verify_tables(tables, pl, m, mem_bound=memory_bound(kind, p, m))
    c0, c1, lvs = stack_stage_params(params, cfg, p, kind=pl.kind)
    step = build_pipeline_step(cfg, tables, pl, mesh, m, mb_shape,
                               (c0, c1, params["embed"], params["head"]),
                               model_axis=model_axis)

    def grads_fn(params, tokens, labels):
        c0, c1, _ = stack_stage_params(params, cfg, p, kind=pl.kind)
        with mesh:
            loss, g0, g1, ge, gh = step(c0, c1, params["embed"],
                                        params["head"], tokens, labels)
        blocks = unstack_stage_grads(g0, g1, cfg, p, lvs, kind=pl.kind)
        return loss, {"embed": ge, "blocks": blocks, "head": gh}

    return grads_fn, pl


# ---------------------------------------------------------------------------
# ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no allocation).
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs_sds(cfg: ModelConfig, shape: str) -> dict:
    info = SHAPES[shape]
    b, s = info["batch"], info["seq"]
    if info["kind"] == "decode":
        if cfg.frontend == "text":
            return {"tokens": _sds((b, 1), jnp.int32)}
        return {"embeds": _sds((b, 1, cfg.d_model), jnp.float32)}
    if cfg.frontend == "text":
        out = {"tokens": _sds((b, s), jnp.int32)}
    else:
        out = {"embeds": _sds((b, s, cfg.d_model), jnp.float32)}
    out["labels"] = _sds((b, s), jnp.int32)
    return out


def params_sds(cfg: ModelConfig) -> dict:
    """Stacked-params ShapeDtypeStructs via eval_shape of the init."""
    def init(key):
        p = M.init_params(key, cfg)
        return {"embed": p["embed"],
                "blocks": M.stack_blocks(p["blocks"], M.period_of(cfg)),
                "head": p["head"]}

    return jax.eval_shape(init, _sds((2,), jnp.uint32))


def opt_state_sds(params_tree) -> dict:
    zeros = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                         params_tree)
    return {"mu": zeros, "nu": zeros,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def caches_sds(cfg: ModelConfig, shape: str):
    info = SHAPES[shape]
    return jax.eval_shape(
        lambda: M.init_caches_stacked(cfg, info["batch"], info["seq"]))


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    """All lowering inputs for (cfg, shape): params (+opt/caches) + batch."""
    info = SHAPES[shape]
    out = {"params": params_sds(cfg), "batch": batch_specs_sds(cfg, shape)}
    if info["kind"] == "train":
        out["opt_state"] = opt_state_sds(out["params"])
    if info["kind"] == "decode":
        out["caches"] = caches_sds(cfg, shape)
        out["pos"] = _sds((), jnp.int32)
    return out
