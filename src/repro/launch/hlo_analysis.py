"""Transparent HLO cost model for the dry-run roofline.

``compiled.cost_analysis()`` proved unreliable for large partitioned
modules (while bodies counted once, fusion-internal accesses inflating
bytes), so the roofline terms are derived by parsing the *optimized,
partitioned* HLO text directly — shapes there are per-chip:

  * FLOPs      — every ``dot`` op: 2 x prod(result dims) x prod(contracting
                 dims); dots inside fusion/while bodies are attributed to
                 each call site (x trip count for bounded loops when the
                 analysis variant is unrolled there are none that matter).
  * HBM bytes  — post-fusion traffic: for each op at the top level of an
                 executed computation, result bytes + operand bytes.
                 Fusion internals don't touch HBM; a fusion's footprint is
                 its operands + result, which is exactly how this counts.
  * collective — result-shape bytes of all-reduce / all-gather /
                 reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "f64": 8, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
                "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# Header lines are gated on shape (top-level, "->", trailing "{") before
# this regex runs, so it only extracts the name.  Don't try to match the
# parameter list: tuple-typed params (conditional branch regions) nest
# parens, which `\([^)]*\)` cannot span.
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]\S*))\s+"
    r"([a-z][a-z0-9\-]*)\((.*)$")
_DIMS_RE = {
    "lhs_c": re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}"),
    "lhs_b": re.compile(r"lhs_batch_dims=\{([0-9,]*)\}"),
}
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                      r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")


def _parse_shapes(text: str):
    """All (dtype, dims) array shapes inside a type string (handles
    tuples)."""
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


def _nbytes(text: str) -> int:
    total = 0
    for dt, shape in _parse_shapes(text):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Op:
    name: str
    type_str: str
    kind: str
    rest: str


@dataclass
class Computation:
    name: str
    ops: dict = field(default_factory=dict)      # name -> Op
    order: list = field(default_factory=list)


def parse_module(hlo: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if not line.startswith(" ") and ("->" in line) and stripped.endswith("{"):
            m = _COMP_HDR.match(stripped)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if stripped.startswith("ENTRY"):
                    entry = cur.name
                continue
        if stripped == "}":
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.ops[op.name] = op
            cur.order.append(op.name)
    return {"computations": comps, "entry": entry}


def _dot_flops(op: Op, comp: Computation, comps: dict) -> float:
    res = _parse_shapes(op.type_str)
    if not res:
        return 0.0
    n_res = 1
    for d in res[0][1]:
        n_res *= d
    lhs_m = _DIMS_RE["lhs_c"].search(op.rest)
    if not lhs_m:
        return 2.0 * n_res            # unknown contraction; assume K=1
    # find lhs operand shape
    opnames = _OPERAND_RE.findall(op.rest.split("),")[0] + ")")
    k = 1
    if opnames:
        lhs = comp.ops.get(opnames[0])
        lhs_shape = None
        if lhs is not None:
            ls = _parse_shapes(lhs.type_str)
            lhs_shape = ls[0][1] if ls else None
        else:
            # operand may be a parameter: shape is embedded inline
            inline = _parse_shapes(op.rest)
            lhs_shape = inline[0][1] if inline else None
        if lhs_shape:
            for i in (int(x) for x in lhs_m.group(1).split(",") if x):
                if i < len(lhs_shape):
                    k *= lhs_shape[i]
    return 2.0 * n_res * k


def _called(op: Op) -> list[str]:
    names = []
    for m in _CALL_RE.finditer(op.rest):
        for n in m.group(1).split(","):
            names.append(n.strip().lstrip("%"))
    return names


_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "reshape"}


def analyze(hlo: str, while_trips: int = 1) -> dict:
    """-> {flops, bytes, collective_bytes, collectives:{...}, n_while}.
    ``while_trips`` multiplies the cost of while bodies (1 for the unrolled
    analysis variants; the rolled full model is only used for memory)."""
    mod = parse_module(hlo)
    comps = mod["computations"]
    memo: dict[tuple, tuple] = {}

    def comp_cost(name: str, depth=0):
        key = (name,)
        if key in memo:
            return memo[key]
        c = comps.get(name)
        if c is None or depth > 50:
            return (0.0, 0.0, {}, 0)
        flops = 0.0
        nbytes = 0.0
        coll: dict[str, float] = {}
        n_while = 0
        for opname in c.order:
            op = c.ops[opname]
            if op.kind == "dot":
                flops += _dot_flops(op, c, comps)
            if op.kind in COLLECTIVES or (
                    op.kind.endswith("-start")
                    and op.kind[:-6] in COLLECTIVES):
                base = op.kind[:-6] if op.kind.endswith("-start") else op.kind
                coll[base] = coll.get(base, 0.0) + _nbytes(op.type_str)
            # bytes: top-level result + operands (resolved in-computation)
            if op.kind not in _SKIP_BYTES and not op.kind.endswith("-done"):
                nbytes += _nbytes(op.type_str)
                for oname in _OPERAND_RE.findall(op.rest):
                    src = c.ops.get(oname)
                    if src is not None:
                        nbytes += _nbytes(src.type_str)
            mult = while_trips if op.kind == "while" else 1
            if op.kind == "while":
                n_while += 1
            for callee in _called(op):
                f2, b2, c2, w2 = comp_cost(callee, depth + 1)
                flops += mult * f2
                for k, v in c2.items():
                    coll[k] = coll.get(k, 0.0) + mult * v
                n_while += w2
                if op.kind in ("while", "conditional", "call"):
                    nbytes += mult * b2      # loop/call bodies touch HBM
        memo[key] = (flops, nbytes, coll, n_while)
        return memo[key]

    f, b, coll, nw = comp_cost(mod["entry"])
    return {"flops": f, "bytes": b,
            "collective_bytes": sum(coll.values()),
            "collectives": {k: int(v) for k, v in coll.items()},
            "n_while": nw}


# ---------------------------------------------------------------------------
# Exposed-vs-hidden collective accounting (overlap verification).
# ---------------------------------------------------------------------------

_GROUPS_RE = re.compile(
    r"(?:replica_groups|source_target_pairs)=\{(\{[0-9,]+\}(?:,\{[0-9,]+\})*)\}")
_GROUP_RE = re.compile(r"\{([0-9,]+)\}")
_GTE_IDX_RE = re.compile(r"index=(\d+)")


def _device_groups(op: Op) -> list[list[int]]:
    m = _GROUPS_RE.search(op.rest)
    if not m:
        return []
    return [[int(x) for x in g.split(",") if x]
            for g in _GROUP_RE.findall(m.group(1))]


def _is_tp_collective(op: Op, tp_size: int) -> bool:
    """A collective is a model-axis (TP) one iff every replica group /
    permute pair stays within one aligned contiguous block of ``tp_size``
    devices — the mesh is built as devices.reshape(pp, tp), so TP peers
    share ``id // tp_size`` while stage peers never do."""
    if tp_size <= 1:
        return False
    groups = _device_groups(op)
    if not groups:
        return False
    return all(len({d // tp_size for d in g}) == 1 for g in groups)


def collective_overlap(hlo: str, tp_size: int = 1) -> dict:
    """Structural exposed-vs-hidden classification of every collective site.

    For each collective op, walk forward in program order tracking the set
    of ops that (transitively) depend on it; the walk ends at the first
    dependent *heavy* op (a ``dot``, or a fusion/call/branch whose body
    contains one) or at the end of the computation.  The collective is
    **hidden** iff at least one heavy op *independent* of it lies inside
    that window — i.e. the scheduler has matmul work to run while the
    collective is in flight.  A blocking collective immediately consumed by
    its own unit's next matmul has an empty window and counts **exposed**.

    Taint through ``tuple`` / ``opt-barrier`` / ``get-tuple-element`` is
    tracked *element-wise*: ``opt-barrier`` output element k is by HLO
    dataflow semantics exactly input element k, so a barrier tying
    (ring state, partner state) — the braid uses one at every interleave
    point to pin schedule order — must not leak the ring's taint onto the
    partner's matmuls.  Cross-element barrier edges are scheduling-only.

    Sites are counted once per syntactic position (a site inside a while
    body executes every trip but counts once), split into ``tp`` (model-
    axis, see ``_is_tp_collective``) and ``other`` (stage-axis ppermutes,
    global psums).  Returns per-class dicts with counts, result bytes and
    ``exposed_share`` (exposed bytes / total bytes; 0.0 when empty).
    """
    mod = parse_module(hlo)
    comps = mod["computations"]
    heavy_memo: dict[str, bool] = {}

    def comp_has_dot(name: str, depth=0) -> bool:
        if name in heavy_memo:
            return heavy_memo[name]
        c = comps.get(name)
        if c is None or depth > 50:
            return False
        heavy_memo[name] = False          # break recursion cycles
        found = False
        for opname in c.order:
            op = c.ops[opname]
            if op.kind == "dot":
                found = True
                break
            if any(comp_has_dot(callee, depth + 1)
                   for callee in _called(op)):
                found = True
                break
        heavy_memo[name] = found
        return found

    def is_heavy(op: Op) -> bool:
        if op.kind == "dot":
            return True
        if op.kind in ("fusion", "call", "while", "conditional",
                       "custom-call"):
            return any(comp_has_dot(cn) for cn in _called(op))
        return False

    stats = {"tp": {"n": 0, "n_hidden": 0, "bytes": 0, "bytes_hidden": 0},
             "other": {"n": 0, "n_hidden": 0, "bytes": 0, "bytes_hidden": 0}}

    for comp in comps.values():
        order = comp.order
        ops = [comp.ops[n] for n in order]
        operands = [set(_OPERAND_RE.findall(op.rest)) for op in ops]
        heavy = [is_heavy(op) for op in ops]
        for i, op in enumerate(ops):
            base = op.kind[:-6] if op.kind.endswith("-start") else op.kind
            if base not in COLLECTIVES:
                continue
            tainted = {op.name}
            elem: dict[str, set[int]] = {}   # tuple op -> tainted indices
            hidden = False
            for j in range(i + 1, len(order)):
                oj = ops[j]
                if oj.kind == "tuple":
                    idx = {e for e, nm in
                           enumerate(_OPERAND_RE.findall(oj.rest))
                           if nm in tainted or nm in elem}
                    if idx:
                        elem[oj.name] = idx
                    continue
                if oj.kind == "opt-barrier":
                    names = _OPERAND_RE.findall(oj.rest)
                    if len(names) == 1:      # identity on one tuple value
                        nm = names[0]
                        if nm in elem:
                            elem[oj.name] = set(elem[nm])
                        elif nm in tainted:
                            tainted.add(oj.name)
                    else:                    # defensive: variadic form
                        idx = {e for e, nm in enumerate(names)
                               if nm in tainted or nm in elem}
                        if idx:
                            elem[oj.name] = idx
                    continue
                if oj.kind == "get-tuple-element":
                    names = _OPERAND_RE.findall(oj.rest)
                    nm = names[0] if names else None
                    im = _GTE_IDX_RE.search(oj.rest)
                    if nm in elem and im is not None:
                        if int(im.group(1)) in elem[nm]:
                            tainted.add(oj.name)
                    elif nm in tainted:
                        tainted.add(oj.name)
                    continue
                # any other consumer of a partially-tainted tuple is
                # conservatively tainted
                if operands[j] & tainted or operands[j] & elem.keys():
                    if heavy[j]:
                        break             # first dependent matmul: window ends
                    tainted.add(oj.name)
                elif heavy[j]:
                    hidden = True         # independent matmul in the window
            cls = "tp" if _is_tp_collective(op, tp_size) else "other"
            nb = _nbytes(op.type_str)
            stats[cls]["n"] += 1
            stats[cls]["bytes"] += nb
            if hidden:
                stats[cls]["n_hidden"] += 1
                stats[cls]["bytes_hidden"] += nb

    for s in stats.values():
        s["n_exposed"] = s["n"] - s["n_hidden"]
        s["bytes_exposed"] = s["bytes"] - s["bytes_hidden"]
        s["exposed_share"] = (s["bytes_exposed"] / s["bytes"]
                              if s["bytes"] else 0.0)
    return stats
