import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh, report memory/cost/collective analysis for §Roofline.

The two lines above MUST precede any other import (jax locks the device
count at first init); smoke tests and benchmarks never import this module.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out d]
"""
import argparse
import json
import re
import sys
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import ASSIGNED, get_config
from repro.launch import steps as S
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import (ShardOptions, batch_specs, cache_specs,
                                    param_specs, to_named)
from repro.optim.adamw import OptConfig

# TPU v5e hardware constants (§Roofline)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\][^ ]*)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.M)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "f64": 8, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "u1": 1}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str, loop_trips: int = 1) -> dict:
    """Per-chip bytes moved by collectives, summed from the result shapes of
    every collective op in the partitioned module.  (all-reduce: result ==
    operand; all-gather: result == bytes received; reduce-scatter: operand
    bytes ~ result x group -- we count result shapes uniformly, a consistent
    lower-bound proxy used for relative comparisons.)

    Collectives whose metadata places them inside a ``while`` body (the
    layer scan — the only rolled loop containing collectives in our graphs)
    are multiplied by ``loop_trips`` (= n_layers / period)."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "count": 0}
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        shape_txt, op = m.group(1), m.group(2)
        mult = loop_trips if "/while/" in line else 1
        out[op] += _shape_bytes(shape_txt) * mult
        out["count"] += 1
    out["total"] = sum(v for k, v in out.items()
                       if k not in ("count", "total"))
    return out


def model_flops(cfg, shape: str) -> float:
    """6·N·D (train) / 2·N·D (fwd-only), N = active params."""
    n = active_params(cfg)
    info = S.SHAPES[shape]
    tokens = info["batch"] * (info["seq"] if info["kind"] != "decode" else 1)
    mult = 6 if info["kind"] == "train" else 2
    return float(mult * n * tokens)


def active_params(cfg) -> float:
    d, hd = cfg.d_model, cfg.hd
    total = cfg.vocab * d * (1 if cfg.causal or cfg.frontend == "text" else 0)
    total += cfg.vocab * d                      # head
    for spec in cfg.layers:
        if spec.mixer == "attn":
            total += d * (cfg.n_heads + 2 * cfg.kv_heads) * hd \
                + cfg.n_heads * hd * d
        elif spec.mixer == "mamba":
            di = cfg.ssm_expand * d
            total += 2 * d * di + di * d
        elif spec.mixer in ("mlstm", "slstm"):
            du = (2 if spec.mixer == "mlstm" else 1) * d
            total += (3 if spec.mixer == "mlstm" else 4) * d * du + du * d
        if spec.mlp == "gated":
            total += 3 * d * cfg.d_ff
        elif spec.mlp == "plain":
            total += 2 * d * cfg.d_ff
        elif spec.mlp == "moe":
            k = cfg.moe.top_k
            e_active = (3 if cfg.moe.gated else 2) * d * cfg.moe.d_ff
            total += k * e_active + d * cfg.moe.num_experts
    return float(total)


def _compile_one(cfg, shape, mesh, opts, remat):
    info = S.SHAPES[shape]
    sds = S.input_specs(cfg, shape)
    p_specs = param_specs(sds["params"], mesh, cfg, opts)
    b_specs = batch_specs(sds["batch"], opts, mesh)
    if info["kind"] == "train":
        step = S.make_train_step(cfg, OptConfig(), remat=remat)
        in_sh = (to_named(p_specs, mesh),
                 to_named({"mu": p_specs, "nu": p_specs,
                           "step": jax.sharding.PartitionSpec()}, mesh),
                 to_named(b_specs, mesh))
        args = (sds["params"], sds["opt_state"], sds["batch"])
    elif info["kind"] == "prefill":
        step = S.make_prefill_step(cfg)
        in_sh = (to_named(p_specs, mesh), to_named(b_specs, mesh))
        args = (sds["params"], sds["batch"])
    else:
        step = S.make_serve_step(cfg)
        c_specs = cache_specs(sds["caches"], cfg, info["batch"], mesh, opts)
        in_sh = (to_named(p_specs, mesh), to_named(c_specs, mesh),
                 to_named(b_specs, mesh),
                 jax.sharding.NamedSharding(
                     mesh, jax.sharding.PartitionSpec()))
        args = (sds["params"], sds["caches"], sds["batch"], sds["pos"])
    from repro.models import attention_core as AC
    from repro.models import units as U
    bh_axes = tuple(opts.data_axes) + (opts.model_axis,)
    # NOTE (§Perf, refuted): pinning the dispatch buffers via
    # units._MOE_SHARD regressed collectives ~14x — GSPMD's own resolution
    # of the expert-parallel scatter beats the hand-pinned layout.  The
    # hint mechanism stays available but is never enabled here.
    with mesh, AC.bh_sharding(bh_axes):
        lowered = jax.jit(step, in_shardings=in_sh).lower(*args)
        compiled = lowered.compile()
    return compiled


def _cost_of(compiled) -> tuple[float, float, dict]:
    """Per-chip (flops, hbm bytes, collectives) from the transparent HLO
    parser (repro.launch.hlo_analysis) — XLA's cost_analysis counts rolled
    while bodies once and inflates bytes with fusion internals."""
    from repro.launch.hlo_analysis import analyze
    r = analyze(compiled.as_text())
    coll = dict(r["collectives"])
    coll["total"] = r["collective_bytes"]
    coll["count"] = r.get("n_while", 0)
    return r["flops"], r["bytes"], coll


def _depth_variant(cfg, n_layers: int):
    period = n_layers  # layers[:n] always forms its own period
    import dataclasses as dc
    return dc.replace(cfg, layers=cfg.layers[:n_layers])


def lower_pair(arch: str, shape: str, *, multi_pod: bool = False,
               opts: ShardOptions = None, remat: bool = True,
               fast_attn: bool = False):
    """Full-depth compile proves the pair lowers (and gives the memory
    analysis); two *unrolled* shallow variants (1 and 2 periods) give exact
    per-layer flops/bytes/collectives — XLA's cost_analysis counts a rolled
    while body once, so full-model costs are reconstructed as
    cost(1) + (reps-1) * [cost(2) - cost(1)]."""
    from repro.models import attention_core as AC
    from repro.models.model import period_of
    cfg = get_config(arch)
    ok, why = S.shape_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "skipped": why}
    if arch == "gemma3-12b" and shape == "long_500k":
        cfg = S.gemma_long_variant(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    opts = opts or ShardOptions(
        data_axes=("pod", "data") if multi_pod else ("data",))
    info = S.SHAPES[shape]

    import contextlib
    fast = AC.fast_attention_math() if fast_attn else contextlib.nullcontext()
    t0 = time.time()
    with fast:
        compiled = _compile_one(cfg, shape, mesh, opts, remat)
    t1 = time.time()

    period = period_of(cfg)
    reps = cfg.n_layers // period
    fast = AC.fast_attention_math() if fast_attn else contextlib.nullcontext()
    with AC.unroll_for_analysis(), fast:
        c1 = _compile_one(_depth_variant(cfg, period), shape, mesh, opts,
                          remat)
        f1, b1, coll1 = _cost_of(c1)
        if reps > 1:
            c2 = _compile_one(_depth_variant(cfg, 2 * period), shape, mesh,
                              opts, remat)
            f2, b2, coll2 = _cost_of(c2)
        else:
            f2, b2, coll2 = f1, b1, coll1
    t2 = time.time()
    k = reps - 1
    # XLA may fuse/partition the two depth variants differently; a negative
    # per-layer delta is measurement noise — clamp and flag.
    noisy = (f2 < f1) or (b2 < b1)
    flops = f1 + k * max(f2 - f1, 0.0)
    bytes_hbm = b1 + k * max(b2 - b1, 0.0)
    keys = set(coll1) | set(coll2)
    coll = {key: coll1.get(key, 0) + k * max(
        coll2.get(key, 0) - coll1.get(key, 0), 0) for key in keys}

    mem = compiled.memory_analysis()
    chips = int(np.prod(list(mesh.shape.values())))
    mflops = model_flops(cfg, shape)
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_hbm / HBM_BW,
        "collective_s": coll["total"] / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    result = {
        "arch": arch, "shape": shape, "mesh": "x".join(
            f"{k}={v}" for k, v in mesh.shape.items()),
        "chips": chips,
        "compile_s": round(t1 - t0, 1),
        "analysis_compile_s": round(t2 - t1, 1),
        "delta_noise": noisy,
        "per_chip": {
            "hlo_flops": flops,
            "hlo_bytes": bytes_hbm,
            "collective_bytes": coll["total"],
            "collectives": {k: v for k, v in coll.items()
                            if k not in ("total",)},
        },
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0),
        } if mem is not None else None,
        "roofline": {
            **{k: round(v, 6) for k, v in terms.items()},
            "dominant": dominant,
            "model_flops_total": mflops,
            "model_flops_per_chip": mflops / chips,
            "useful_flops_frac": (mflops / chips) / flops if flops else None,
        },
    }
    return result


ALL_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=ALL_SHAPES)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--expert-parallel", action="store_true")
    ap.add_argument("--zero-data", action="store_true")
    ap.add_argument("--fast-attn", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    pairs = []
    if args.all:
        for a in ASSIGNED:
            for s in ALL_SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    opts = None
    tag = ""
    if args.expert_parallel or args.zero_data:
        opts = ShardOptions(
            data_axes=("pod", "data") if args.multi_pod else ("data",),
            expert_parallel=args.expert_parallel, zero_data=args.zero_data)
        tag = ("_ep" if args.expert_parallel else "") + \
              ("_zero" if args.zero_data else "")
    if args.fast_attn:
        tag += "_fast"
    tag += args.tag
    failures = 0
    for arch, shape in pairs:
        name = f"{arch}_{shape}_{'pod2' if args.multi_pod else 'pod1'}{tag}"
        try:
            res = lower_pair(arch, shape, multi_pod=args.multi_pod,
                             opts=opts, remat=not args.no_remat,
                             fast_attn=args.fast_attn)
        except Exception as e:  # noqa: BLE001 - report and continue
            res = {"arch": arch, "shape": shape, "error": repr(e)[:2000]}
            failures += 1
        (outdir / f"{name}.json").write_text(json.dumps(res, indent=1))
        if "error" in res:
            print(f"[FAIL] {name}: {res['error'][:200]}", flush=True)
        elif "skipped" in res:
            print(f"[SKIP] {name}: {res['skipped']}", flush=True)
        else:
            r = res["roofline"]
            print(f"[OK]   {name}: compile={res['compile_s']}s "
                  f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s"
                  f" coll={r['collective_s']:.4f}s dom={r['dominant']}",
                  flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
