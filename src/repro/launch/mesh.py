"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: one pod = 16x16 = 256 chips (data, model);
    multi-pod = 2 pods = 512 chips with a leading 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_pipeline_mesh(p: int, tp: int, data: int = 1, ep: int = 1):
    """Mesh for the STP pipeline runtime: (data, stage[, expert], model).

    The ``expert`` axis (MoE expert parallelism) is only materialised when
    ``ep > 1`` so non-MoE callers keep the historical 3-axis mesh."""
    if ep > 1:
        return jax.make_mesh((data, p, ep, tp),
                             ("data", "stage", "expert", "model"))
    return jax.make_mesh((data, p, tp), ("data", "stage", "model"))
