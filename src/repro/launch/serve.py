"""Serving driver: continuous-batching engine CLI over the ``repro.serve``
subsystem (mesh-resident params, paged KV pool, batched prefill).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
      --batch 4 --prompt-len 32 --gen 16 --tp 2 --data 2 --check

Timing protocol: one warmup request per prompt-length bucket triggers jit
compilation of the prefill/decode programs, results are synced
(``block_until_ready`` happens implicitly — the engine pulls every tick's
tokens to host), metrics are reset, and only then is the measured batch
submitted.  The old driver timed a single ``time.time()`` span around the
first call, so it mostly measured XLA compilation.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serve import Engine, EngineConfig, reference, stacked_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (2 layers, d_model=128, vocab=512)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--rows", type=int, default=8)
    ap.add_argument("--blocks", type=int, default=64)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--check", action="store_true",
                    help="compare greedy output against the token-at-a-time "
                         "reference oracle")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced(n_layers=2, d_model=128, n_heads=4, vocab=512)
    if not cfg.causal:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode path")
    if cfg.frontend != "text":
        raise SystemExit(f"{cfg.name} decodes text continuations only in "
                         "this driver (use --arch with a text frontend)")

    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    max_seq = args.prompt_len + args.gen + 1
    eng = Engine(cfg, params, EngineConfig(
        tp=args.tp, data=args.data, rows=args.rows, blocks=args.blocks,
        block_size=args.block_size, max_seq=max(64, 2 * max_seq),
        prefill_group=min(args.batch, max(2, args.rows // 2))))
    prompts = np.asarray(
        jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab),
        np.int32)

    # Warmup: same prompt-length bucket as the measured batch, so both the
    # prefill and decode programs are compiled before the clock starts.
    eng.generate([prompts[0]], min(args.gen, 2))
    eng.reset_metrics()

    outs = eng.generate(list(prompts), args.gen)
    s = eng.metrics.summary()
    assert s["completed"] == args.batch
    for o in outs:
        assert o.shape == (args.prompt_len + args.gen,)
    print(f"completed {s['completed']} requests ({s['gen_tokens']} tokens) "
          f"in {s['elapsed_s']:.2f}s ({s['tokens_per_s']:.1f} tok/s)")
    print(f"ttft p50 {s['ttft_ms']['p50']:.1f}ms  "
          f"latency p50 {s['latency_ms']['p50']:.1f}ms  "
          f"ticks {s['ticks']}")
    print("sample:", outs[0][-args.gen:])

    if args.check:
        st = stacked_params(cfg, params)
        ref = np.asarray(reference.generate(cfg, st, prompts, args.gen,
                                            max_seq=max_seq))
        ok = all(np.array_equal(outs[i], ref[i]) for i in range(args.batch))
        print("reference check:", "MATCH" if ok else "MISMATCH")
        if not ok:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
