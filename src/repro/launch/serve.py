"""Serving driver: batched prefill + autoregressive decode with KV/state
caches (ring buffers for sliding-window layers, recurrent states for SSMs).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.tp.context import TPContext


def generate(cfg, params_stacked, prompts, max_new: int, *,
             max_seq: int = 512, greedy: bool = True, key=None):
    """prompts (b, p) int32 -> (b, p+max_new).  Prefill via repeated decode
    steps (teacher-forced), then sample; one jitted step serves both."""
    b, plen = prompts.shape
    caches = M.init_caches_stacked(cfg, b, max_seq)

    @jax.jit
    def step(caches, tok, pos):
        nxt, logits, caches = M.decode_step(
            params_stacked, caches, {"tokens": tok[:, None]}, pos, cfg)
        return caches, nxt, logits

    toks = [prompts[:, i] for i in range(plen)]
    nxt = None
    for pos in range(plen):
        caches, nxt, _ = step(caches, toks[pos], jnp.int32(pos))
    out = list(toks)
    cur = nxt
    for pos in range(plen, plen + max_new):
        out.append(cur)
        caches, cur, _ = step(caches, cur, jnp.int32(pos))
    return jnp.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced(n_layers=2, d_model=128, n_heads=4, vocab=512)
    if not cfg.causal:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode path")
    if cfg.frontend != "text":
        raise SystemExit(f"{cfg.name} decodes text continuations only in "
                         "this driver (use --arch with a text frontend)")

    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    period = M.period_of(cfg)
    stacked = {"embed": params["embed"],
               "blocks": M.stack_blocks(params["blocks"], period),
               "head": params["head"]}
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    t0 = time.time()
    out = generate(cfg, stacked, prompts, args.gen,
                   max_seq=args.prompt_len + args.gen + 1)
    dt = time.time() - t0
    assert out.shape == (args.batch, args.prompt_len + args.gen)
    assert not np.any(np.isnan(np.asarray(out, np.float32)))
    print(f"generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", np.asarray(out[0, -args.gen:]))


if __name__ == "__main__":
    main()
