import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=256"

"""Pipeline-runtime dry-run: lower + compile the shard_map executor for any
schedule kind — the braided F/B/W instruction streams, ppermute stage
exchanges and TP collectives — on a production (data, stage, model) mesh.
Proves the ``stage`` axis of the paper's runtime shards (the train_step
dry-run covers the (data, model) axes).

By default this lowers the *fused* train step (schedule execution +
global-norm clip + in-mesh AdamW on mesh-resident state — what
``SpmdRunner`` executes); ``--grads-only`` lowers the grads-returning step
the differential tests use.

  PYTHONPATH=src python -m repro.launch.dryrun_pipeline \
      --arch stablelm-3b --pp 4 --tp 4 --microbatches 8
  PYTHONPATH=src python -m repro.launch.dryrun_pipeline \
      --arch stablelm-3b --schedule 1f1b --pp 8 --tp 2 --grads-only
"""
import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.schedule import build as build_schedule, partition
from repro.launch.hlo_analysis import analyze
from repro.models import model as M
from repro.optim import OptConfig
from repro.pipeline.spmd import (build_pipeline_step,
                                 build_pipeline_train_step,
                                 stack_stage_params)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--schedule", default="stp")
    ap.add_argument("--pp", type=int, default=4)
    ap.add_argument("--tp", type=int, default=4)
    ap.add_argument("--data", type=int, default=16)
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--mb-batch", type=int, default=2)
    ap.add_argument("--grads-only", action="store_true",
                    help="lower the grads-returning step instead of the "
                         "fused train step")
    ap.add_argument("--vit-factor", type=float, default=1.0,
                    help="cost multiplier on virtual stage 0 (VLM frontend) "
                         "for the cost-balanced layer partition")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    mesh = jax.make_mesh((args.data, args.pp, args.tp),
                         ("data", "stage", "model"))
    tables, pl = build_schedule(args.schedule, args.pp, args.microbatches)
    part = partition(cfg, pl.n_vs, vit_factor=args.vit_factor)
    sizes = [b - a for a, b in part]
    print(f"[partition] {cfg.n_layers} layers over {pl.n_vs} virtual "
          f"stages: {'/'.join(map(str, sizes))}"
          + (f" (vit_factor={args.vit_factor})"
             if args.vit_factor != 1.0 else ""))

    def init_sds():
        p = M.init_params(jax.random.PRNGKey(0), cfg)
        c0, c1, _ = stack_stage_params(p, cfg, args.pp, kind=pl.kind,
                                       part=part)
        return c0, c1, p["embed"], p["head"]

    trees = jax.eval_shape(init_sds)
    c0, c1, embed_p, head_p = trees
    m, b, s = args.microbatches, args.mb_batch, args.seq
    tokens = jax.ShapeDtypeStruct((m, b, s), jnp.int32)
    labels = jax.ShapeDtypeStruct((m, b, s), jnp.int32)

    t0 = time.time()
    if args.grads_only:
        step = build_pipeline_step(cfg, tables, pl, mesh, m, (b, s), trees,
                                   model_axis="model", part=part)
        lower_args = (c0, c1, embed_p, head_p, tokens, labels)
    else:
        step = build_pipeline_train_step(
            cfg, tables, pl, mesh, m, (b, s), trees, OptConfig(),
            model_axis="model", part=part)
        params = {"c0": c0, "c1": c1, "embed": embed_p, "head": head_p}
        zeros = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        opt = {"mu": zeros, "nu": zeros,
               "step": jax.ShapeDtypeStruct((), jnp.int32)}
        lower_args = (params, opt, tokens, labels)
    with mesh:
        lowered = step.lower(*lower_args)
        compiled = lowered.compile()
    dt = time.time() - t0
    mem = compiled.memory_analysis()
    r = analyze(compiled.as_text())
    res = {
        "arch": cfg.name, "schedule": args.schedule,
        "step": "grads" if args.grads_only else "fused_train",
        "mesh": f"data={args.data}xstage={args.pp}xmodel={args.tp}",
        "partition": sizes,
        "chips": args.data * args.pp * args.tp,
        "microbatches": m, "compile_s": round(dt, 1),
        "peak_gb_per_chip": round(((getattr(mem, "argument_size_in_bytes",
                                            0) or 0)
                                   + (getattr(mem, "temp_size_in_bytes", 0)
                                      or 0)) / 2 ** 30, 2),
        "collectives": r["collectives"],
        "collective_gb_per_chip": round(r["collective_bytes"] / 2 ** 30, 2),
        "n_while": r["n_while"],
    }
    Path(args.out).mkdir(parents=True, exist_ok=True)
    name = f"pipeline_{cfg.name}_{args.schedule}_pp{args.pp}_tp{args.tp}"
    (Path(args.out) / f"{name}.json").write_text(json.dumps(res, indent=1))
    print("[OK]", json.dumps(res))


if __name__ == "__main__":
    main()
