"""One train loop for all runtimes: the ``Runner`` protocol.

Every runner exposes

    runner.layout                      # the TrainState layout it consumes
    runner.init_state(params, opt)     # canonical -> layout TrainState
    runner.step(state, batch)          # -> (state', {"loss", "gnorm"})
    runner.describe                    # short tag for log lines

over a *global* batch dict (``{"tokens"|"embeds", "labels"}``), so
``launch.train`` (and any benchmark) is a single loop regardless of
runtime:

  PjitRunner      — data-parallel jit train_step on period-stacked params.
  ReferenceRunner — any schedule through the single-process reference
                    executor (numerics oracle) + host AdamW.
  SpmdRunner      — any schedule through the shard_map runtime on a real
                    (stage[, model]) mesh with the AdamW update fused
                    under shard_map: params and moments are mesh-resident
                    and never round-trip the host between steps.
"""
from __future__ import annotations

from typing import Optional, Protocol

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.schedule import (build as build_schedule, memory_bound,
                                 partition)
from repro.core.simulator import annotate_offload, verify_tables
from repro.data import DataConfig, microbatches
from repro.launch.state import Layout, TrainState, decay_mask
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import OptConfig, adamw_update
from repro.pipeline.reference import pipeline_grads
from repro.pipeline.spmd import (activation_buffer_stats,
                                 build_pipeline_train_step,
                                 stack_stage_params, stage_param_specs)


class Runner(Protocol):
    layout: Layout
    describe: str

    def init_state(self, params, opt=None) -> TrainState: ...

    def step(self, state: TrainState, batch: dict
             ) -> tuple[TrainState, dict]: ...


def _batch_key(cfg: ModelConfig) -> str:
    return "tokens" if cfg.frontend == "text" else "embeds"


class PjitRunner:
    """jit train_step over period-stacked params (the dry-run's step at
    real, reduced scale)."""

    def __init__(self, cfg: ModelConfig, oc: OptConfig):
        self.cfg, self.oc = cfg, oc
        self.layout = Layout("period", cfg.n_layers,
                             period=M.period_of(cfg))
        self.describe = "pjit"

        @jax.jit
        def _step(state: TrainState, batch):
            loss, grads = jax.value_and_grad(
                lambda p: M.loss_fn(p, batch, cfg))(state.params)
            mask = decay_mask(state.params, state.layout)
            p2, o2, gn = adamw_update(state.params, grads, state.opt, oc,
                                      decay_mask=mask)
            return TrainState(p2, o2, state.layout), loss, gn

        self._step = _step

    def init_state(self, params, opt=None) -> TrainState:
        return TrainState.from_canonical(params, self.layout, opt=opt)

    def step(self, state, batch):
        state, loss, gn = self._step(state, batch)
        return state, {"loss": loss, "gnorm": gn}


class ReferenceRunner:
    """Schedule-table execution through the single-process reference
    executor; canonical params, host AdamW."""

    def __init__(self, cfg: ModelConfig, oc: OptConfig, kind: str, p: int,
                 m: int, *, part=None, vit_factor: float = 1.0):
        self.cfg, self.oc, self.m = cfg, oc, m
        self.tables, self.pl = build_schedule(kind, p, m)
        self.part = partition(cfg, self.pl.n_vs, ranges=part,
                              vit_factor=vit_factor)
        self.layout = Layout("canonical", cfg.n_layers)
        self.describe = f"{kind} p={p} m={m}"

    def init_state(self, params, opt=None) -> TrainState:
        return TrainState.from_canonical(params, self.layout, opt=opt)

    def step(self, state, batch):
        mbs = microbatches(batch, self.m)
        loss, grads = pipeline_grads(state.params, mbs, self.tables,
                                     self.pl, self.cfg, part=self.part)
        p2, o2, gn = adamw_update(state.params, grads, state.opt, self.oc)
        return TrainState(p2, o2, state.layout), {"loss": loss, "gnorm": gn}


class SpmdRunner:
    """shard_map runtime on a (stage[, model]) mesh with in-mesh AdamW.

    The fused step (``pipeline.spmd.build_pipeline_train_step``) consumes
    and produces mesh-resident stacked params + moments, so the per-step
    host ``stack_stage_params`` round-trip of the old ``grads_fn`` path is
    gone: the host only touches microbatch tokens/labels.
    """

    def __init__(self, cfg: ModelConfig, oc: OptConfig, kind: str, p: int,
                 m: int, mb_shape, *, tp: int = 1, ep: int = 1,
                 mesh: Optional[Mesh] = None, fuse_slots: bool = True,
                 braid_tp: bool = False, part=None, vit_factor: float = 1.0,
                 offload_alpha: float = 0.0):
        self.cfg, self.oc, self.m = cfg, oc, m
        self.offload_alpha = alpha = float(offload_alpha)
        if not 0.0 <= alpha < 1.0:
            raise ValueError(f"offload_alpha must be in [0, 1), got {alpha}")
        if ep > 1:
            if cfg.moe is None:
                raise ValueError(f"ep={ep} needs a MoE config")
            if cfg.moe.num_experts % ep:
                raise ValueError(
                    f"ep={ep} must divide num_experts={cfg.moe.num_experts}")
        if mesh is None:
            ndev = len(jax.devices())
            if p * ep * tp != ndev:
                raise ValueError(
                    f"spmd runtime needs pp*ep*tp == device count (pp={p}, "
                    f"ep={ep}, tp={tp}, devices={ndev}); set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count=N")
            if ep > 1:
                mesh = Mesh(np.array(jax.devices()).reshape(p, ep, tp),
                            ("stage", "expert", "model"))
            else:
                mesh = Mesh(np.array(jax.devices()).reshape(p, tp),
                            ("stage", "model"))
        self.mesh = mesh
        tables, pl = build_schedule(kind, p, m)
        if alpha > 0.0:
            # Statically check the offload-annotated lifetimes (and the
            # offload-aware memory bound) of the table the executor lowers.
            verify_tables(annotate_offload(tables, pl), pl, m,
                          mem_bound=memory_bound(kind, p, m,
                                                 offload_alpha=alpha),
                          offload_alpha=alpha)
        else:
            verify_tables(tables, pl, m, mem_bound=memory_bound(kind, p, m))
        self.pl = pl
        bounds = partition(cfg, pl.n_vs, ranges=part, vit_factor=vit_factor)
        self.part = bounds
        self.layout = Layout("stage", cfg.n_layers, p=p,
                             placement=pl.kind, bounds=bounds)
        sizes = [b - a for a, b in bounds]
        ptag = ("" if len(set(sizes)) == 1
                else " part=" + "/".join(map(str, sizes)))
        self.describe = (f"spmd {kind} {pl.kind} p={p}"
                         + (f" ep={ep}" if ep > 1 else "")
                         + f" tp={tp} m={m}"
                         + (" braid" if braid_tp else "")
                         + (f" off={alpha:g}" if alpha > 0 else "") + ptag)
        model_axis = "model" if tp > 1 else None
        expert_axis = "expert" if ep > 1 else None

        def sds(key):
            prm = M.init_params(key, cfg)
            c0, c1, _ = stack_stage_params(prm, cfg, p, kind=pl.kind,
                                           part=bounds)
            return c0, c1, prm["embed"], prm["head"]

        trees = jax.eval_shape(sds, jax.ShapeDtypeStruct((2,), jnp.uint32))
        self.act_stats = activation_buffer_stats(
            cfg, pl, m, mb_shape, trees, tp_size=tp, ep_size=ep, part=bounds,
            offload_alpha=alpha)
        self._step = build_pipeline_train_step(
            cfg, tables, pl, mesh, m, mb_shape, trees, oc,
            model_axis=model_axis, expert_axis=expert_axis,
            fuse_slots=fuse_slots, braid_tp=braid_tp, part=bounds,
            offload_alpha=alpha)
        pspec = stage_param_specs(trees, model_axis=model_axis,
                                  expert_axis=expert_axis)
        self._shardings = {
            "params": jax.tree.map(lambda s: NamedSharding(mesh, s), pspec),
            "opt": {"mu": jax.tree.map(lambda s: NamedSharding(mesh, s),
                                       pspec),
                    "nu": jax.tree.map(lambda s: NamedSharding(mesh, s),
                                       pspec),
                    "step": NamedSharding(mesh, P())},
        }

    def init_state(self, params, opt=None) -> TrainState:
        """Stack canonical params/moments once and place them on the mesh;
        after this, steps never re-stack host-side."""
        st = TrainState.from_canonical(params, self.layout, opt=opt)
        return TrainState(
            jax.device_put(st.params, self._shardings["params"]),
            jax.device_put(st.opt, self._shardings["opt"]),
            self.layout)

    def step(self, state, batch):
        mbs = microbatches(batch, self.m)
        key = _batch_key(self.cfg)
        tokens = jnp.stack([b[key] for b in mbs])
        labels = jnp.stack([b["labels"] for b in mbs])
        with self.mesh:
            p2, o2, loss, gn = self._step(state.params, state.opt,
                                          tokens, labels)
        return TrainState(p2, o2, state.layout), {"loss": loss, "gnorm": gn}


def make_runner(runtime: str, cfg: ModelConfig, oc: OptConfig,
                dc: DataConfig, *, schedule: str = "stp", pp: int = 2,
                tp: int = 1, ep: int = 1, mesh: Optional[Mesh] = None,
                fuse_slots: bool = True, braid_tp: bool = False,
                part=None, vit_factor: float = 1.0,
                offload_alpha: float = 0.0) -> Runner:
    """Factory over the three runtimes ('pjit' | 'pipeline' | 'spmd').

    ``fuse_slots`` (spmd only) selects the segment-fused slot lowering
    (static branch dispatch + pruned exchanges); pass ``False`` to force
    the generic one-switch-per-slot scan, e.g. for differential debugging.
    ``braid_tp`` (spmd only) lowers composite F&B slots through the
    braided overlap-aware chunk executor.
    ``part`` / ``vit_factor`` (pipeline + spmd) choose the per-virtual-stage
    layer partition: explicit ranges, or cost-balanced via
    ``core.schedule.partition`` with stage 0's cost scaled by
    ``vit_factor`` (VLM frontend).
    ``ep`` (spmd only) shards MoE experts over an ``expert`` mesh axis
    between ``stage`` and ``model``; routing stays replicated, so training
    matches ``ep=1`` exactly.
    ``offload_alpha`` (spmd only) enables §4.4 activation offload: the
    fraction α of every chunk-0 activation context lives in host memory
    between its F and a double-buffered FETCH one slot ahead of its B
    (α=0 traces exactly the baseline program).
    """
    if runtime == "pjit":
        return PjitRunner(cfg, oc)
    if runtime == "spmd":
        mb = dc.global_batch // dc.microbatches
        return SpmdRunner(cfg, oc, schedule, pp, dc.microbatches,
                          (mb, dc.seq_len), tp=tp, ep=ep, mesh=mesh,
                          fuse_slots=fuse_slots, braid_tp=braid_tp,
                          part=part, vit_factor=vit_factor,
                          offload_alpha=offload_alpha)
    if runtime == "pipeline":
        return ReferenceRunner(cfg, oc, schedule, pp, dc.microbatches,
                               part=part, vit_factor=vit_factor)
    raise ValueError(f"unknown runtime {runtime!r}")
