"""Shard-aware numpy checkpointing.

Pytrees are flattened to path-keyed ``.npz`` shards.  Sharded (pjit) arrays
are gathered per-leaf with ``jax.device_get`` (fine at the test/example
scale; a production deployment would write per-host shards — the format
already keys leaves by path so that extension is additive).  Metadata
(treedef repr, step, config name) travels in ``meta.json``.
"""
from __future__ import annotations

import json
import os
import re
from pathlib import Path

import jax
import numpy as np

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return _SAFE.sub("_", "/".join(parts) or "leaf")


def save_checkpoint(directory, tree, *, step: int = 0, extra: dict = None,
                    shard_leaves: int = 256):
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    names, arrays = [], []
    for path, leaf in flat:
        names.append(_path_str(path))
        arrays.append(np.asarray(jax.device_get(leaf)))
    # dedupe collisions deterministically
    seen = {}
    for i, n in enumerate(names):
        if n in seen:
            names[i] = f"{n}__{i}"
        seen[n] = i
    for shard in range(0, len(names), shard_leaves):
        part = {n: a for n, a in zip(names[shard:shard + shard_leaves],
                                     arrays[shard:shard + shard_leaves])}
        np.savez(d / f"shard_{shard // shard_leaves:05d}.npz", **part)
    meta = {"step": step, "n_leaves": len(names), "names": names,
            "extra": extra or {}}
    (d / "meta.json").write_text(json.dumps(meta))


def load_checkpoint(directory, like_tree):
    """Restore into the structure of ``like_tree`` (leaf order must match
    the saved order, which path-keying makes stable).

    Raises ``ValueError`` — never a stripped-under-``-O`` assert or a bare
    ``KeyError`` — when the checkpoint does not match ``like_tree``: leaf
    count mismatch, a leaf name missing from the shards (truncated or
    foreign checkpoint), or a stored dtype/shape that differs from the
    target leaf (silent ``astype`` coercion would mask corruption)."""
    d = Path(directory)
    meta = json.loads((d / "meta.json").read_text())
    store = {}
    for f in sorted(d.glob("shard_*.npz")):
        with np.load(f) as z:
            store.update({k: z[k] for k in z.files})
    flat, treedef = jax.tree_util.tree_flatten(like_tree)
    if len(flat) != meta["n_leaves"]:
        raise ValueError(
            f"checkpoint {d}: leaf count mismatch — target tree has "
            f"{len(flat)} leaves, meta.json records {meta['n_leaves']}")
    missing = [n for n in meta["names"] if n not in store]
    if missing:
        raise ValueError(
            f"checkpoint {d}: {len(missing)} leaves named in meta.json are "
            f"absent from the shard files (truncated or foreign "
            f"checkpoint); first missing: {missing[0]!r}")
    out = []
    for n, l in zip(meta["names"], flat):
        v = np.asarray(store[n])
        want = np.dtype(l.dtype)
        if v.dtype != want:
            raise ValueError(
                f"checkpoint {d}: dtype mismatch at leaf {n!r} — stored "
                f"{v.dtype}, target expects {want}")
        if v.shape != tuple(l.shape):
            raise ValueError(
                f"checkpoint {d}: shape mismatch at leaf {n!r} — stored "
                f"{v.shape}, target expects {tuple(l.shape)}")
        out.append(v)
    return jax.tree_util.tree_unflatten(treedef, out), meta["step"], \
        meta["extra"]
