"""Training data pipeline.

A deterministic synthetic corpus (Zipf-distributed token stream with a
Markov low-order structure so the loss actually decreases) with document
packing, causal-LM label shifting, microbatch slicing for the pipeline
schedules, and host-side sharding helpers for the ``data`` mesh axis.

The modality frontends use the same pipeline: ``audio``/``vlm`` configs
consume precomputed frame/patch embeddings (the assignment's stub
carve-out), which we synthesize as smoothed Gaussian features with a token
alignment so labels remain well-defined.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seq_len: int = 256
    global_batch: int = 8
    microbatches: int = 1
    seed: int = 0
    zipf_a: float = 1.2
    markov_order: int = 2
    pad_id: int = 0
    mask_ratio: float = 0.15        # encoder-only (hubert) masked prediction


class SyntheticTextDataset:
    """Infinite deterministic token stream with learnable structure."""

    def __init__(self, cfg: ModelConfig, dc: DataConfig):
        self.cfg = cfg
        self.dc = dc
        self.rng = np.random.default_rng(dc.seed)
        v = cfg.vocab
        # low-rank Markov transition: next ~ mix(unigram, f(prev))
        self.unigram = self._zipf(v)
        k = min(64, v)
        self.proj = self.rng.integers(0, k, size=v)
        self.cluster_next = self._zipf_rows(k, v)

    def _zipf(self, v):
        w = 1.0 / np.arange(1, v + 1) ** self.dc.zipf_a
        w = w / w.sum()
        return w[self.rng.permutation(v)]

    def _zipf_rows(self, k, v):
        rows = np.stack([self._zipf(v) for _ in range(k)])
        return rows / rows.sum(-1, keepdims=True)

    def sample_tokens(self, batch: int, seq: int) -> np.ndarray:
        out = np.empty((batch, seq), np.int64)
        out[:, 0] = self.rng.choice(self.cfg.vocab, size=batch,
                                    p=self.unigram)
        for t in range(1, seq):
            rows = self.cluster_next[self.proj[out[:, t - 1]]]
            mix = 0.7 * rows + 0.3 * self.unigram[None]
            mix = mix / mix.sum(-1, keepdims=True)
            cum = np.cumsum(mix, axis=-1)
            u = self.rng.random((batch, 1))
            out[:, t] = (u > cum).sum(-1)
        return out.astype(np.int32)


def pack_documents(tokens: np.ndarray, seq: int, eod: int = 1) -> np.ndarray:
    """Pack a ragged list of docs into fixed (n, seq) rows with EOD."""
    flat = []
    for doc in tokens:
        flat.extend(list(doc))
        flat.append(eod)
    n = len(flat) // seq
    return np.asarray(flat[: n * seq], np.int32).reshape(n, seq)


def make_batches(cfg: ModelConfig, dc: DataConfig, steps: int
                 ) -> Iterator[dict]:
    """Yields global batches: causal LM (tokens/labels shifted), encoder
    masked-prediction (hubert), or embed-frontend (audio/vlm stubs)."""
    ds = SyntheticTextDataset(cfg, dc)
    rng = np.random.default_rng(dc.seed + 1)
    b, s = dc.global_batch, dc.seq_len
    for _ in range(steps):
        toks = ds.sample_tokens(b, s + 1)
        if cfg.frontend == "text":
            if cfg.causal:
                yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
            else:
                inp = toks[:, :-1].copy()
                lab = np.full_like(inp, -1)
                mask = rng.random(inp.shape) < dc.mask_ratio
                lab[mask] = inp[mask] % cfg.vocab
                inp[mask] = dc.pad_id
                yield {"tokens": inp, "labels": lab}
        else:
            # stub frontend: embeddings aligned to the token stream so the
            # LM objective is learnable (embedding = table lookup + noise).
            table = np.asarray(
                np.random.default_rng(7).normal(
                    size=(cfg.vocab, cfg.d_model)), np.float32) * 0.1
            emb = table[toks[:, :-1]] + rng.normal(
                size=(b, s, cfg.d_model)).astype(np.float32) * 0.01
            if cfg.causal:
                yield {"embeds": emb, "labels": toks[:, 1:]}
            else:
                lab = np.full((b, s), -1, np.int64)
                mask = rng.random((b, s)) < dc.mask_ratio
                lab[mask] = toks[:, :-1][mask] % cfg.vocab
                yield {"embeds": emb, "labels": lab.astype(np.int32)}


def microbatches(batch: dict, m: int) -> list[dict]:
    """Split a global batch into m microbatches along the batch dim."""
    b = next(iter(batch.values())).shape[0]
    assert b % m == 0, f"global batch {b} not divisible by {m} microbatches"
    k = b // m
    return [{key: v[i * k:(i + 1) * k] for key, v in batch.items()}
            for i in range(m)]
