from repro.data.pipeline import (DataConfig, SyntheticTextDataset,
                                 make_batches, microbatches)
