"""SSM / recurrent mixer units: Mamba (Jamba's 7-of-8 layers), mLSTM and
sLSTM (xLSTM blocks).

Unit decomposition mirrors the attention unit: the big in/out projections are
split-B/W linears; the recurrent *core* (conv + selective scan / gated
recurrence — parameter-light relative to the projections) takes joint B+W
gradients via ``core_vjp`` (DESIGN.md §4 deviation note).

The sequence scan is chunked with ``jax.checkpoint`` so the saved-residual
memory of the backward pass is O(seq/chunk · state) instead of O(seq · state)
— this is what lets jamba's 16k-wide mamba states lower at seq 4k–524k.

Each core also exposes a single-step variant for autoregressive decode
(``serve_step``), carrying an explicit recurrent state instead of a KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import autograd as ag
from repro.models.config import LayerSpec, ModelConfig
from repro.tp.context import TPContext

SCAN_CHUNK = 64


def chunked_scan(step, init, xs, chunk: int = SCAN_CHUNK):
    """lax.scan over time with per-chunk rematerialization.

    xs leaves are (s, ...); full chunks scan under ``jax.checkpoint`` (the
    backward stores only inter-chunk carries and recomputes inside each
    chunk); the remainder runs as an exact un-chunked tail so the final
    carry is the true step-s state (decode/prefill handoff relies on it)."""
    s = jax.tree_util.tree_leaves(xs)[0].shape[0]
    n = s // chunk
    tail = s - n * chunk
    ys_parts = []
    carry = init

    if n:
        head = jax.tree.map(
            lambda a: a[: n * chunk].reshape((n, chunk) + a.shape[1:]), xs)

        @jax.checkpoint
        def chunk_fn(carry, xc):
            return jax.lax.scan(step, carry, xc)

        carry, ys_h = jax.lax.scan(chunk_fn, carry, head)
        ys_parts.append(jax.tree.map(
            lambda a: a.reshape((n * chunk,) + a.shape[2:]), ys_h))
    if tail:
        xt = jax.tree.map(lambda a: a[n * chunk:], xs)
        carry, ys_t = jax.lax.scan(step, carry, xt)
        ys_parts.append(ys_t)
    if len(ys_parts) == 1:
        return carry, ys_parts[0]
    ys = jax.tree.map(lambda *p: jnp.concatenate(p, axis=0), *ys_parts)
    return carry, ys


def init_state_like(mixer: str, params, batch: int):
    """Fresh decode state for one layer, with dims derived from the *param
    shapes* (not the config) so it is correct for TP-sharded per-rank params
    inside ``shard_map`` as well as for full params on the host."""
    if mixer == "mamba":
        cp = params["core"]
        di, n = cp["A_log"].shape
        ck = cp["conv_w"].shape[-1]
        return {"h": jnp.zeros((batch, di, n), jnp.float32),
                "conv": jnp.zeros((batch, ck - 1, di), jnp.float32)}
    if mixer == "mlstm":
        nh, hd = params["wq"].shape[0], params["wq"].shape[1]
        return {"C": jnp.zeros((batch, nh, hd, hd), jnp.float32),
                "n": jnp.zeros((batch, nh, hd), jnp.float32),
                "m": jnp.full((batch, nh), -1e30, jnp.float32)}
    if mixer == "slstm":
        du = params["w_x"].shape[-1] // 4
        z = jnp.zeros((batch, du), jnp.float32)
        return {"c": z, "n": z, "h": z,
                "m": jnp.full((batch, du), -1e30, jnp.float32)}
    raise ValueError(mixer)


def prefill_scan(step_fn, params, tp: TPContext, x_ln, x_res, state, lengths,
                 cfg: ModelConfig):
    """Whole-prompt prefill for a recurrent mixer in ONE ``lax.scan`` of its
    single-token decode step — the serve engine's batched-prefill path.

    Because the scanned body *is* the decode step, the handed-off state is
    bit-identical to replaying the prompt token-at-a-time (the oracle in
    ``repro.serve.reference``).  ``lengths`` (b,) freezes each row's state
    past its (right-padded) prompt, so padding to a shared bucket length
    never perturbs the state; outputs at padded positions are garbage and
    the caller masks them.  Returns (y (b, s, d), final_state)."""
    b, s, _ = x_ln.shape
    tm = lambda a: jnp.moveaxis(a, 1, 0)[:, :, None]      # (s, b, 1, d)

    def body(st, inp):
        xl_t, xr_t, t = inp
        y, st2 = step_fn(params, tp, xl_t, xr_t, st, cfg)
        keep = t < lengths
        st = jax.tree.map(
            lambda new, old: jnp.where(
                keep.reshape((b,) + (1,) * (new.ndim - 1)),
                new.astype(old.dtype), old),
            st2, st)
        return st, y[:, 0]

    state, ys = jax.lax.scan(
        body, state, (tm(x_ln), tm(x_res), jnp.arange(s, dtype=jnp.int32)))
    return jnp.moveaxis(ys, 0, 1), state


# ---------------------------------------------------------------------------
# Mamba (selective SSM).
# ---------------------------------------------------------------------------

def mamba_dims(cfg: ModelConfig, tp_size: int = 1):
    di = cfg.ssm_expand * cfg.d_model // tp_size      # local inner dim
    r = max(1, cfg.d_model // 16)                     # dt rank
    return di, r, cfg.ssm_state, cfg.ssm_conv


def _causal_conv(x, w, b):
    """Depthwise causal conv. x (b, s, di), w (di, ck)."""
    ck = w.shape[-1]
    out = jnp.zeros_like(x)
    for j in range(ck):
        shifted = jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[None, None, :, ck - 1 - j]
    return out + b[None, None, :]


def mamba_core_fn(cfg: ModelConfig, tp: TPContext):
    def core(cp, x_, z):
        b, s, _ = x_.shape
        di = cp["A_log"].shape[0]
        x_ = jax.nn.silu(_causal_conv(x_, cp["conv_w"], cp["conv_b"]))
        # B/C/dt-rank projection contracts the (TP-sharded) inner dim ->
        # partial sums; the All-Reduce here is tiny (r + 2n wide).
        bcdt = tp.psum(jnp.einsum("bsd,dr->bsr", x_, cp["w_x"]))
        r = cp["w_dt"].shape[0]
        n = cp["A_log"].shape[1]
        dt_r, B, C_ = bcdt[..., :r], bcdt[..., r:r + n], bcdt[..., r + n:]
        dt = jax.nn.softplus(jnp.einsum("bsr,rd->bsd", dt_r, cp["w_dt"])
                             + cp["dt_bias"][None, None])
        A = -jnp.exp(cp["A_log"].astype(jnp.float32))  # (di, n)

        def step(h, inp):
            dt_t, x_t, B_t, C_t = inp                  # time-major slices
            dA = jnp.exp(dt_t[..., None] * A[None])    # (b, di, n)
            h = dA * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
            y_t = jnp.einsum("bdn,bn->bd", h, C_t)
            return h, y_t

        init = jnp.zeros((b, di, n), jnp.float32)
        tm = lambda a: jnp.moveaxis(a, 1, 0)           # time-major
        _, y = chunked_scan(step, init,
                            (tm(dt.astype(jnp.float32)),
                             tm(x_.astype(jnp.float32)),
                             tm(B.astype(jnp.float32)),
                             tm(C_.astype(jnp.float32))))
        y = jnp.moveaxis(y, 0, 1) + cp["D"][None, None] * x_
        return (y * jax.nn.silu(z)).astype(x_.dtype)

    return core


def mamba_fwd(params, tp: TPContext, x_ln, x_res, spec: LayerSpec,
              cfg: ModelConfig):
    x_, _ = ag.linear_fwd(x_ln, params["w_in_x"])
    z, _ = ag.linear_fwd(x_ln, params["w_in_z"])
    a, core_saved = ag.core_vjp(mamba_core_fn(cfg, tp), params["core"], x_, z)
    part, _ = ag.linear_fwd(a, params["w_out"])
    y = tp.fuse_residual(part, x_res)
    return y, (x_ln, core_saved, a)


def mamba_bwd_act(params, tp: TPContext, ctx, gy, spec: LayerSpec,
                  cfg: ModelConfig):
    x_ln, core_saved, a = ctx
    g_res = gy
    g_a = ag.linear_bwd_act(gy, params["w_out"])
    core_pgrads, (g_x, g_z) = ag.core_bwd(mamba_core_fn(cfg, tp), core_saved,
                                          g_a)
    gx_ln = tp.psum_out(ag.linear_bwd_act(g_x, params["w_in_x"])
                        + ag.linear_bwd_act(g_z, params["w_in_z"]))
    wtape = {"w_in_x": ag.tape_entry(x_ln, g_x),
             "w_in_z": ag.tape_entry(x_ln, g_z),
             "w_out": ag.tape_entry(a, gy)}
    return gx_ln, g_res, wtape, {"core": core_pgrads}


def mamba_bwd_weight(wtape):
    return {k: ag.tape_weight(e) for k, e in wtape.items()}


def mamba_init_state(cfg: ModelConfig, batch: int, tp_size: int = 1,
                     dtype=jnp.float32):
    di, r, n, ck = mamba_dims(cfg, tp_size)
    return {"h": jnp.zeros((batch, di, n), jnp.float32),
            "conv": jnp.zeros((batch, ck - 1, di), dtype)}


def mamba_step(params, tp: TPContext, x_ln, x_res, state, cfg: ModelConfig):
    """Single-token decode step. x_ln (b, 1, d)."""
    cp = params["core"]
    n = cp["A_log"].shape[1]
    r = cp["w_dt"].shape[0]
    x_ = jnp.einsum("bsd,df->bsf", x_ln, params["w_in_x"])[:, 0]
    z = jnp.einsum("bsd,df->bsf", x_ln, params["w_in_z"])[:, 0]
    window = jnp.concatenate([state["conv"], x_[:, None, :]], axis=1)
    # taps aligned with _causal_conv: out_t = sum_j x_{t-j} * w[:, ck-1-j]
    ck = cp["conv_w"].shape[-1]
    conv = sum(window[:, ck - 1 - j, :] * cp["conv_w"][:, ck - 1 - j]
               for j in range(ck))
    x_c = jax.nn.silu(conv + cp["conv_b"])
    bcdt = tp.psum(jnp.einsum("bd,dr->br", x_c, cp["w_x"]))
    dt_r, B, C_ = bcdt[..., :r], bcdt[..., r:r + n], bcdt[..., r + n:]
    dt = jax.nn.softplus(jnp.einsum("br,rd->bd", dt_r, cp["w_dt"])
                         + cp["dt_bias"][None])
    A = -jnp.exp(cp["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt[..., None].astype(jnp.float32) * A[None])
    h = dA * state["h"] + (dt * x_c)[..., None].astype(jnp.float32) \
        * B[:, None, :].astype(jnp.float32)
    y = jnp.einsum("bdn,bn->bd", h, C_.astype(jnp.float32)) \
        + cp["D"][None] * x_c
    a = (y * jax.nn.silu(z)).astype(x_ln.dtype)[:, None, :]
    part = jnp.einsum("bsd,df->bsf", a, params["w_out"])
    y_out = tp.fuse_residual(part, x_res)
    new_state = {"h": h, "conv": window[:, 1:]}
    return y_out, new_state


# ---------------------------------------------------------------------------
# mLSTM (matrix-memory LSTM, xLSTM).
# ---------------------------------------------------------------------------

def mlstm_dims(cfg: ModelConfig, tp_size: int = 1):
    du = 2 * cfg.d_model // tp_size                   # local up dim (expand 2)
    nh = max(1, cfg.n_heads // tp_size)
    return du, nh, du // nh


def _mlstm_step(carry, inp):
    C, n, m = carry                                    # (b,h,dv,dk) (b,h,dk) (b,h)
    q, k, v, it, ft = inp
    m_new = jnp.maximum(ft + m, it)
    i = jnp.exp(it - m_new)
    f = jnp.exp(ft + m - m_new)
    C = f[..., None, None] * C + i[..., None, None] \
        * (v[..., :, None] * k[..., None, :])
    n = f[..., None] * n + i[..., None] * k
    num = jnp.einsum("bhvk,bhk->bhv", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    h = num / den[..., None]
    return (C, n, m_new), h


def mlstm_core_fn(nh: int, hd: int):
    def core(_, q, k, v, it, ft):
        b, s, _ = q.shape
        sh = lambda a: jnp.moveaxis(
            a.reshape(b, s, nh, -1).astype(jnp.float32), 1, 0)
        qh, kh, vh = sh(q), sh(k) * hd ** -0.5, sh(v)
        itm = jnp.moveaxis(it.astype(jnp.float32), 1, 0)
        ftm = jax.nn.log_sigmoid(jnp.moveaxis(ft.astype(jnp.float32), 1, 0))
        init = (jnp.zeros((b, nh, hd, hd), jnp.float32),
                jnp.zeros((b, nh, hd), jnp.float32),
                jnp.full((b, nh), -1e30, jnp.float32))
        _, h = chunked_scan(_mlstm_step, init, (qh, kh, vh, itm, ftm))
        return jnp.moveaxis(h, 0, 1).reshape(b, s, nh * hd).astype(q.dtype)

    return core


def _mlstm_gated_core(nh: int, hd: int):
    core = mlstm_core_fn(nh, hd)

    def gated_core(_, q_, k_, v_, it_, ft_, z_):
        b, s = q_.shape[:2]
        flat = lambda a: a.reshape(b, s, nh * hd)
        h = core(None, flat(q_), flat(k_), flat(v_), it_, ft_)
        return h * jax.nn.silu(z_)

    return gated_core


def mlstm_fwd(params, tp: TPContext, x_ln, x_res, spec: LayerSpec,
              cfg: ModelConfig):
    # Up projections (column-parallel, heads shard the up dim).
    xu, _ = ag.linear_fwd(x_ln, params["w_upx"])      # (b, s, du_l)
    z, _ = ag.linear_fwd(x_ln, params["w_upz"])
    nh, hd = params["wq"].shape[0], params["wq"].shape[1]
    b, s, du = xu.shape
    xh = xu.reshape(b, s, nh, hd)
    # Head-local (block-diagonal) q/k/v and per-head scalar gates — the
    # TP-shardable analogue of xLSTM's projections (heads shard over TP).
    q, _ = ag.head_linear_fwd(xh, params["wq"])
    k, _ = ag.head_linear_fwd(xh, params["wk"])
    v, _ = ag.head_linear_fwd(xh, params["wv"])
    it = jnp.einsum("bshd,hd->bsh", xh, params["wi"])
    ft = jnp.einsum("bshd,hd->bsh", xh, params["wf"])
    a, core_saved = ag.core_vjp(_mlstm_gated_core(nh, hd), None,
                                q, k, v, it, ft, z)
    part, _ = ag.linear_fwd(a, params["w_down"])
    y = tp.fuse_residual(part, x_res)
    return y, (x_ln, xh, core_saved, a)


def mlstm_bwd_act(params, tp: TPContext, ctx, gy, spec: LayerSpec,
                  cfg: ModelConfig):
    x_ln, xh, core_saved, a = ctx
    g_res = gy
    g_a = ag.linear_bwd_act(gy, params["w_down"])
    nh, hd = params["wq"].shape[0], params["wq"].shape[1]
    _, (gq, gk, gv, git, gft, gz) = ag.core_bwd(
        _mlstm_gated_core(nh, hd), core_saved, g_a)
    g_xh = (ag.head_linear_bwd_act(gq, params["wq"])
            + ag.head_linear_bwd_act(gk, params["wk"])
            + ag.head_linear_bwd_act(gv, params["wv"])
            + jnp.einsum("bsh,hd->bshd", git, params["wi"])
            + jnp.einsum("bsh,hd->bshd", gft, params["wf"]))
    b, s = g_xh.shape[:2]
    g_xu = g_xh.reshape(b, s, nh * hd)
    gx_ln = tp.psum_out(ag.linear_bwd_act(g_xu, params["w_upx"])
                        + ag.linear_bwd_act(gz, params["w_upz"]))
    wtape = {"w_upx": ag.tape_entry(x_ln, g_xu),
             "w_upz": ag.tape_entry(x_ln, gz),
             "wq": ag.tape_entry(xh, gq), "wk": ag.tape_entry(xh, gk),
             "wv": ag.tape_entry(xh, gv),
             "wi": ag.tape_entry(xh, git), "wf": ag.tape_entry(xh, gft),
             "w_down": ag.tape_entry(a, gy)}
    return gx_ln, g_res, wtape, {}


_MLSTM_HEAD_TAPES = {"wq", "wk", "wv"}
_MLSTM_GATE_TAPES = {"wi", "wf"}


def mlstm_bwd_weight(wtape):
    out = {}
    for k, (x, g) in wtape.items():
        if k in _MLSTM_HEAD_TAPES:
            out[k] = ag.head_linear_bwd_weight(x, g)
        elif k in _MLSTM_GATE_TAPES:
            out[k] = jnp.einsum("bshd,bsh->hd", x, g,
                                preferred_element_type=jnp.float32
                                ).astype(g.dtype)
        else:
            out[k] = ag.linear_bwd_weight(x, g)
    return out


def mlstm_init_state(cfg: ModelConfig, batch: int, tp_size: int = 1):
    du, nh, hd = mlstm_dims(cfg, tp_size)
    return {"C": jnp.zeros((batch, nh, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, nh, hd), jnp.float32),
            "m": jnp.full((batch, nh), -1e30, jnp.float32)}


def mlstm_step(params, tp: TPContext, x_ln, x_res, state, cfg: ModelConfig):
    xu = jnp.einsum("bsd,df->bsf", x_ln, params["w_upx"])[:, 0]
    z = jnp.einsum("bsd,df->bsf", x_ln, params["w_upz"])[:, 0]
    nh, hd = params["wq"].shape[0], params["wq"].shape[1]
    b = xu.shape[0]
    du = nh * hd
    xh = xu.reshape(b, nh, hd)
    q = jnp.einsum("bhd,hde->bhe", xh, params["wq"]).astype(jnp.float32)
    k = jnp.einsum("bhd,hde->bhe", xh, params["wk"]).astype(jnp.float32) \
        * hd ** -0.5
    v = jnp.einsum("bhd,hde->bhe", xh, params["wv"]).astype(jnp.float32)
    it = jnp.einsum("bhd,hd->bh", xh, params["wi"]).astype(jnp.float32)
    ft = jax.nn.log_sigmoid(
        jnp.einsum("bhd,hd->bh", xh, params["wf"]).astype(jnp.float32))
    (C, n, m), h = _mlstm_step((state["C"], state["n"], state["m"]),
                               (q, k, v, it, ft))
    a = (h.reshape(b, du) * jax.nn.silu(z)).astype(x_ln.dtype)[:, None]
    part = jnp.einsum("bsd,df->bsf", a, params["w_down"])
    y = tp.fuse_residual(part, x_res)
    return y, {"C": C, "n": n, "m": m}


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory LSTM with block-diagonal recurrence, xLSTM).
# ---------------------------------------------------------------------------

def slstm_dims(cfg: ModelConfig, tp_size: int = 1):
    du = cfg.d_model // tp_size
    nh = max(1, cfg.n_heads // tp_size)
    return du, nh, du // nh


def slstm_core_fn(nh: int, hd: int):
    def core(cp, xw):
        # xw (b, s, 4*du): pre-activations from the input projection.
        b, s, d4 = xw.shape
        du = d4 // 4
        R = cp["r"]                                    # (4, nh, hd, hd)

        def step(carry, xw_t):
            c, n, h, m = carry                         # (b, du) each
            hh = h.reshape(b, nh, hd)
            rec = jnp.einsum("bhd,ghde->gbhe", hh, R).reshape(4, b, du)
            zt = jnp.tanh(xw_t[..., :du] + rec[0])
            it = xw_t[..., du:2 * du] + rec[1]
            ft = xw_t[..., 2 * du:3 * du] + rec[2]
            ot = jax.nn.sigmoid(xw_t[..., 3 * du:] + rec[3])
            m_new = jnp.maximum(ft + m, it)
            i = jnp.exp(it - m_new)
            f = jnp.exp(ft + m - m_new)
            c = f * c + i * zt
            n = f * n + i
            h = ot * c / jnp.maximum(n, 1.0)
            return (c, n, h, m_new), h

        z = jnp.zeros((b, du), jnp.float32)
        init = (z, z, z, jnp.full((b, du), -1e30, jnp.float32))
        _, hs = chunked_scan(step, init,
                             jnp.moveaxis(xw.astype(jnp.float32), 1, 0))
        return jnp.moveaxis(hs, 0, 1).astype(xw.dtype)

    return core


def slstm_fwd(params, tp: TPContext, x_ln, x_res, spec: LayerSpec,
              cfg: ModelConfig):
    xw, _ = ag.linear_fwd(x_ln, params["w_x"])        # (b, s, 4*du_l)
    du = xw.shape[-1] // 4
    nh = params["core"]["r"].shape[1]
    core = slstm_core_fn(nh, du // nh)
    a, core_saved = ag.core_vjp(core, params["core"], xw)
    part, _ = ag.linear_fwd(a, params["w_down"])
    y = tp.fuse_residual(part, x_res)
    return y, (x_ln, core_saved, a)


def slstm_bwd_act(params, tp: TPContext, ctx, gy, spec: LayerSpec,
                  cfg: ModelConfig):
    x_ln, core_saved, a = ctx
    g_res = gy
    g_a = ag.linear_bwd_act(gy, params["w_down"])
    du = a.shape[-1]
    nh = params["core"]["r"].shape[1]
    core = slstm_core_fn(nh, du // nh)
    core_pgrads, (g_xw,) = ag.core_bwd(core, core_saved, g_a)
    gx_ln = tp.psum_out(ag.linear_bwd_act(g_xw, params["w_x"]))
    wtape = {"w_x": ag.tape_entry(x_ln, g_xw), "w_down": ag.tape_entry(a, gy)}
    return gx_ln, g_res, wtape, {"core": core_pgrads}


def slstm_bwd_weight(wtape):
    return {k: ag.tape_weight(e) for k, e in wtape.items()}


def slstm_init_state(cfg: ModelConfig, batch: int, tp_size: int = 1):
    du, nh, hd = slstm_dims(cfg, tp_size)
    z = jnp.zeros((batch, du), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, du), -1e30,
                                                  jnp.float32)}


def slstm_step(params, tp: TPContext, x_ln, x_res, state, cfg: ModelConfig):
    xw = jnp.einsum("bsd,df->bsf", x_ln, params["w_x"])[:, 0]
    du = xw.shape[-1] // 4
    nh = params["core"]["r"].shape[1]
    hd = du // nh
    b = xw.shape[0]
    R = params["core"]["r"]
    c, n, h, m = (state["c"], state["n"], state["h"], state["m"])
    hh = h.reshape(b, nh, hd)
    rec = jnp.einsum("bhd,ghde->gbhe", hh, R).reshape(4, b, du)
    xf = xw.astype(jnp.float32)
    zt = jnp.tanh(xf[..., :du] + rec[0])
    it = xf[..., du:2 * du] + rec[1]
    ft = xf[..., 2 * du:3 * du] + rec[2]
    ot = jax.nn.sigmoid(xf[..., 3 * du:] + rec[3])
    m_new = jnp.maximum(ft + m, it)
    i = jnp.exp(it - m_new)
    f = jnp.exp(ft + m - m_new)
    c = f * c + i * zt
    n = f * n + i
    h = ot * c / jnp.maximum(n, 1.0)
    a = h.astype(x_ln.dtype)[:, None]
    part = jnp.einsum("bsd,df->bsf", a, params["w_down"])
    y = tp.fuse_residual(part, x_res)
    return y, {"c": c, "n": n, "h": h, "m": m_new}
