"""Model assembly: layers → chunks → full models.

Three execution paths share the unit definitions (single source of truth):

* **pjit path** (`forward`, `loss_fn`): global-view arrays, XLA SPMD inserts
  collectives from sharding constraints.  Layers run under `lax.scan` over
  the architecture's *period* (gemma3's 5:1, jamba's 1:7+MoE interleave) so
  compile time is O(period), not O(depth).
* **unit path** (`layer_fwd` / `layer_bwd_act` / `layer_bwd_weight`,
  `chunk_*`): the paper's F/B/W decomposition used by the STP pipeline
  executor and the braided blocks, with explicit TP collectives.
* **serve path** (`decode_layer_step`, prefill helpers): single-token decode
  with KV caches (attention) / recurrent states (SSM).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import autograd as ag
from repro.models import ssm, units
from repro.models.attention_core import flash_attention_inference
from repro.models.config import LayerSpec, ModelConfig
from repro.tp.context import OverlapTP, PendingPsum, TPContext


# ---------------------------------------------------------------------------
# Mixer dispatch tables.
# ---------------------------------------------------------------------------

def _attn_fwd(p, tp, x_ln, x_res, rope, spec, cfg):
    return units.attn_fwd(p, tp, x_ln, x_res, rope, spec, cfg)


def _ssm_fwd(fn):
    def wrapped(p, tp, x_ln, x_res, rope, spec, cfg):
        return fn(p, tp, x_ln, x_res, spec, cfg)
    return wrapped


MIXER_FWD = {
    "attn": _attn_fwd,
    "mamba": _ssm_fwd(ssm.mamba_fwd),
    "mlstm": _ssm_fwd(ssm.mlstm_fwd),
    "slstm": _ssm_fwd(ssm.slstm_fwd),
}
MIXER_BWD_ACT = {
    "attn": units.attn_bwd_act,
    "mamba": ssm.mamba_bwd_act,
    "mlstm": ssm.mlstm_bwd_act,
    "slstm": ssm.slstm_bwd_act,
}
MIXER_BWD_W = {
    "attn": units.attn_bwd_weight,
    "mamba": ssm.mamba_bwd_weight,
    "mlstm": ssm.mlstm_bwd_weight,
    "slstm": ssm.slstm_bwd_weight,
}


def _mlp_fns(spec: LayerSpec):
    if spec.mlp == "moe":
        return units.moe_fwd, units.moe_bwd_act, units.moe_bwd_weight
    return units.mlp_fwd, units.mlp_bwd_act, units.mlp_bwd_weight


# ---------------------------------------------------------------------------
# Layer-level F / B / W  (paper §3: Pre-Attn, Attn, Pre-MLP, MLP units).
# ---------------------------------------------------------------------------

def layer_fwd(params, tp: TPContext, x, rope, spec: LayerSpec,
              cfg: ModelConfig):
    x_ln, c_ln1 = units.prenorm_fwd(params["ln1"], x, cfg)
    y1, c_mix = MIXER_FWD[spec.mixer](params["mixer"], tp, x_ln, x, rope,
                                      spec, cfg)
    if spec.mlp == "none":
        return y1, (c_ln1, c_mix, None, None)
    mlp_fwd, _, _ = _mlp_fns(spec)
    x_ln2, c_ln2 = units.prenorm_fwd(params["ln2"], y1, cfg)
    y2, c_mlp = mlp_fwd(params["mlp"], tp, x_ln2, y1, spec, cfg)
    return y2, (c_ln1, c_mix, c_ln2, c_mlp)


def layer_bwd_act(params, tp: TPContext, ctx, gy, spec: LayerSpec,
                  cfg: ModelConfig):
    c_ln1, c_mix, c_ln2, c_mlp = ctx
    joint = {}
    wtape = {}
    if spec.mlp != "none":
        _, mlp_bwd_act, _ = _mlp_fns(spec)
        gx_ln2, g_res2, wt_mlp, j_mlp = mlp_bwd_act(params["mlp"], tp, c_mlp,
                                                    gy, spec, cfg)
        g_from_ln2, j_ln2 = units.prenorm_bwd(c_ln2, gx_ln2, cfg)
        gy = g_from_ln2 + g_res2
        wtape["mlp"] = wt_mlp
        if j_mlp:
            joint["mlp"] = j_mlp
        joint["ln2"] = j_ln2
    gx_ln1, g_res1, wt_mix, j_mix = MIXER_BWD_ACT[spec.mixer](
        params["mixer"], tp, c_mix, gy, spec, cfg)
    g_from_ln1, j_ln1 = units.prenorm_bwd(c_ln1, gx_ln1, cfg)
    gx = g_from_ln1 + g_res1
    wtape["mixer"] = wt_mix
    if j_mix:
        joint["mixer"] = j_mix
    joint["ln1"] = j_ln1
    return gx, wtape, joint


def layer_bwd_weight(wtape, spec: LayerSpec):
    out = {"mixer": MIXER_BWD_W[spec.mixer](wtape["mixer"])}
    if "mlp" in wtape:
        _, _, mlp_bwd_w = _mlp_fns(spec)
        out["mlp"] = mlp_bwd_w(wtape["mlp"])
    return out


# --- chunk = a contiguous group of layers assigned to one virtual stage ----

def chunk_fwd(layer_params, tp, x, rope, specs, cfg):
    ctxs = []
    for p, spec in zip(layer_params, specs):
        x, c = layer_fwd(p, tp, x, rope, spec, cfg)
        ctxs.append(c)
    return x, ctxs


def chunk_bwd_act(layer_params, tp, ctxs, gy, specs, cfg):
    wtapes, joints = [], []
    for p, c, spec in zip(reversed(layer_params), reversed(ctxs),
                          reversed(specs)):
        gy, wt, j = layer_bwd_act(p, tp, c, gy, spec, cfg)
        wtapes.append(wt)
        joints.append(j)
    return gy, wtapes[::-1], joints[::-1]


def chunk_bwd_weight(wtapes, specs):
    return [layer_bwd_weight(wt, spec) for wt, spec in zip(wtapes, specs)]


# --- braided composite executor (paper §4, Fig. 1) -------------------------

def _braid_f_steps(layer_params, specs, otp, rope, cfg):
    """One entry per unit of the forward chunk: mixer, then (if present)
    MLP.  Each step maps x → (y_or_pending, ctx_piece); the unit-output
    collective comes back as a PendingPsum via the OverlapTP hooks (except
    MoE, whose output is a plain residual add)."""
    steps = []
    for p, spec in zip(layer_params, specs):
        def mix_step(x, p=p, spec=spec):
            x_ln, c_ln1 = units.prenorm_fwd(p["ln1"], x, cfg)
            y, c_mix = MIXER_FWD[spec.mixer](p["mixer"], otp, x_ln, x, rope,
                                             spec, cfg)
            return y, (c_ln1, c_mix)
        steps.append(mix_step)
        if spec.mlp != "none":
            def mlp_step(x, p=p, spec=spec):
                mlp_fwd, _, _ = _mlp_fns(spec)
                x_ln2, c_ln2 = units.prenorm_fwd(p["ln2"], x, cfg)
                y, c_mlp = mlp_fwd(p["mlp"], otp, x_ln2, x, spec, cfg)
                return y, (c_ln2, c_mlp)
            steps.append(mlp_step)
    return steps


def _braid_b_steps(layer_params, ctxs, specs, otp, cfg):
    """One entry per unit of the backward-act chunk, in execution (reversed)
    order: MLP bwd then mixer bwd per layer.  Each step maps
    gy → (gx_ln_or_pending, post) where ``post(gx_ln)`` finishes the unit —
    prenorm backward plus the Eq. (2) residual re-attach — and returns
    (gy_next, (wtape_piece, joint_piece, j_ln))."""
    steps = []
    for p, c, spec in zip(reversed(layer_params), reversed(ctxs),
                          reversed(specs)):
        c_ln1, c_mix, c_ln2, c_mlp = c
        if spec.mlp != "none":
            def bmlp_step(gy, p=p, c_ln2=c_ln2, c_mlp=c_mlp, spec=spec):
                _, mlp_bwd_act, _ = _mlp_fns(spec)
                r, g_res2, wt, j = mlp_bwd_act(p["mlp"], otp, c_mlp, gy,
                                               spec, cfg)
                def post(gx_ln2):
                    g_from, j_ln2 = units.prenorm_bwd(c_ln2, gx_ln2, cfg)
                    return g_from + g_res2, (wt, j, j_ln2)
                return r, post
            steps.append(bmlp_step)
        def bmix_step(gy, p=p, c_ln1=c_ln1, c_mix=c_mix, spec=spec):
            r, g_res1, wt, j = MIXER_BWD_ACT[spec.mixer](p["mixer"], otp,
                                                         c_mix, gy, spec, cfg)
            def post(gx_ln1):
                g_from, j_ln1 = units.prenorm_bwd(c_ln1, gx_ln1, cfg)
                return g_from + g_res1, (wt, j, j_ln1)
            return r, post
        steps.append(bmix_step)
    return steps


def _braid_finish(v):
    return v.finish() if isinstance(v, PendingPsum) else v


def chunk_fwd_bwd_braided(f_layer_params, x, b_layer_params, b_ctxs, gy,
                          tp: TPContext, rope, specs, cfg: ModelConfig,
                          b_specs=None):
    """Interleave a forward chunk with a backward-act chunk at unit
    granularity so each side's TP collective hides under the partner's
    matmuls (paper §4, Fig. 1).

    ``b_specs`` names the backward chunk's layer specs when the two chunks
    cover different stage ranges (heterogeneous partitions); it defaults to
    ``specs`` (both chunks the same shape).  The braid loop itself already
    tolerates unequal unit counts — the longer side simply runs its tail
    un-partnered.

    Numerically equivalent to

        y, f_ctxs = chunk_fwd(f_layer_params, tp, x, rope, specs, cfg)
        gx, wts, js = chunk_bwd_act(b_layer_params, tp, b_ctxs, gy,
                                    b_specs or specs, cfg)

    (bitwise at ``tp.size <= 2``; ring reassociation beyond that) and
    returns ``(y, f_ctxs, gx, wts, js)``.

    Interleave order per steady-state iteration — F-ring, B-compute, B-ring,
    F-compute — is chosen so that every ring chain has the *partner* side's
    matmuls between its hops and its first dependent matmul:

        [F_{i} ring] [B_j compute] [B_j ring] [F_{i+1} compute] ...

    The F_i ring's result is next consumed by F_{i+1}'s compute, with B_j's
    matmuls in between; the B_j ring's result is consumed by B_{j+1}'s
    compute, with F_{i+1}'s matmuls in between.  Units whose output is not a
    deferrable collective (MoE) degrade gracefully: the braid still
    alternates their compute with the partner's.

    Trace order alone does not survive compilation: XLA's sequential
    (memory-minimizing) scheduler freely hoists the partner's independent
    matmuls away from the ring hops they are meant to hide.  Each
    interleave point is therefore pinned with ``lax.optimization_barrier``
    tying (own-side state, partner state).  The barrier is an element-wise
    identity — dataflow still keeps the partner's matmuls independent of
    the ring (no value crosses elements; bitwise-equality tests hold) —
    but the scheduler must now place them after the hops and before the
    ring's consumer.
    """
    otp = OverlapTP(tp)
    if b_specs is None:
        b_specs = specs
    f_steps = _braid_f_steps(f_layer_params, specs, otp, rope, cfg)
    b_steps = _braid_b_steps(b_layer_params, b_ctxs, b_specs, otp, cfg)

    f_pieces, b_pieces = [], []
    pend_f = None
    state_f, state_b = x, gy
    fi = bi = 0
    while fi < len(f_steps) or bi < len(b_steps) or pend_f is not None:
        # F-side ring hops: traced here, immediately before the B unit's
        # matmuls, which are what hide them.
        if pend_f is not None:
            state_f = _braid_finish(pend_f)
            pend_f = None
            # B compute must be scheduled after the F hops it hides.
            state_f, state_b = jax.lax.optimization_barrier(
                (state_f, state_b))
        # B unit compute, then its ring — hidden under the F unit below.
        if bi < len(b_steps):
            r, post = b_steps[bi](state_b)
            bi += 1
            state_b, piece = post(_braid_finish(r))
            b_pieces.append(piece)
            # F compute must be scheduled after the B dots (so they sit
            # inside the F ring's window) and after the B hops it hides.
            state_f, state_b = jax.lax.optimization_barrier(
                (state_f, state_b))
        # F unit compute; its pending finishes next iteration.
        if fi < len(f_steps):
            pend_f, piece = f_steps[fi](state_f)
            fi += 1
            f_pieces.append(piece)
    y, gx = state_f, state_b

    # Reassemble chunk_fwd's per-layer ctx tuples from the unit pieces.
    f_ctxs, it = [], iter(f_pieces)
    for spec in specs:
        c_ln1, c_mix = next(it)
        if spec.mlp == "none":
            f_ctxs.append((c_ln1, c_mix, None, None))
        else:
            c_ln2, c_mlp = next(it)
            f_ctxs.append((c_ln1, c_mix, c_ln2, c_mlp))

    # Reassemble chunk_bwd_act's per-layer wtape/joint dicts (reversed-order
    # pieces → layer order, mirroring layer_bwd_act's key structure).
    wtapes, joints, it = [], [], iter(b_pieces)
    for spec in reversed(b_specs):
        wtape, joint = {}, {}
        if spec.mlp != "none":
            wt_mlp, j_mlp, j_ln2 = next(it)
            wtape["mlp"] = wt_mlp
            if j_mlp:
                joint["mlp"] = j_mlp
            joint["ln2"] = j_ln2
        wt_mix, j_mix, j_ln1 = next(it)
        wtape["mixer"] = wt_mix
        if j_mix:
            joint["mixer"] = j_mix
        joint["ln1"] = j_ln1
        wtapes.append(wtape)
        joints.append(joint)
    return y, f_ctxs, gx, wtapes[::-1], joints[::-1]


# ---------------------------------------------------------------------------
# Embedding & head units.
# ---------------------------------------------------------------------------

def embed_fwd(params, batch, cfg: ModelConfig):
    """batch: {"tokens": (b,s) int} or {"embeds": (b,s,d)} per cfg.frontend."""
    if cfg.frontend == "text":
        tokens = batch["tokens"]
        x = jnp.take(params["emb"], tokens, axis=0)
        return x, ("emb", tokens)
    embeds = batch["embeds"]
    x, _ = ag.linear_fwd(embeds, params["proj"])
    return x, ("proj", embeds)


def embed_bwd_weight(params, ctx, gx):
    kind, saved = ctx
    if kind == "emb":
        demb = jnp.zeros_like(params["emb"]).at[saved].add(gx)
        return {"emb": demb}
    return {"proj": ag.linear_bwd_weight(saved, gx)}


def head_fwd(params, tp: TPContext, x, labels, cfg: ModelConfig):
    """Final norm + LM head + vocab-parallel cross entropy.

    labels (b, s) int32; positions with label < 0 are masked out.
    Returns (loss, ctx).  In unit (shard_map) mode the head weight is
    column-parallel over vocab and the softmax statistics are reduced with
    pmax/psum (Megatron-style vocab-parallel CE)."""
    x_ln, c_ln = units.prenorm_fwd(params["ln_f"], x, cfg)
    logits, _ = ag.linear_fwd(x_ln, params["w_lm"])
    lf = logits.astype(jnp.float32)
    m = tp.pmax(jax.lax.stop_gradient(lf.max(axis=-1)))
    sumexp = tp.psum(jnp.exp(lf - m[..., None]).sum(axis=-1))
    lse = jnp.log(sumexp) + m
    v_local = logits.shape[-1]
    off = tp.axis_index() * v_local
    lab_loc = labels - off
    inb = (lab_loc >= 0) & (lab_loc < v_local)
    picked_loc = jnp.take_along_axis(
        lf, jnp.clip(lab_loc, 0, v_local - 1)[..., None], axis=-1)[..., 0]
    picked = tp.psum(jnp.where(inb, picked_loc, 0.0))
    valid = (labels >= 0).astype(jnp.float32)
    nvalid = jnp.maximum(valid.sum(), 1.0)
    loss = ((lse - picked) * valid).sum() / nvalid
    ctx = (c_ln, x_ln, logits, lse, lab_loc, inb, valid, nvalid)
    return loss, ctx


def head_bwd_act(params, tp: TPContext, ctx, g_loss, cfg: ModelConfig):
    c_ln, x_ln, logits, lse, lab_loc, inb, valid, nvalid = ctx
    v_local = logits.shape[-1]
    p = jnp.exp(logits.astype(jnp.float32) - lse[..., None])
    onehot = jax.nn.one_hot(jnp.where(inb, lab_loc, -1), v_local,
                            dtype=jnp.float32)
    g_logits = ((p - onehot) * (valid / nvalid)[..., None]
                * g_loss).astype(logits.dtype)
    gx_ln = tp.psum(ag.linear_bwd_act(g_logits, params["w_lm"]))
    gx, j_ln = units.prenorm_bwd(c_ln, gx_ln, cfg)
    wtape = {"w_lm": ag.tape_entry(x_ln, g_logits)}
    return gx, wtape, {"ln_f": j_ln}


def head_bwd_weight(wtape):
    return {"w_lm": ag.tape_weight(wtape["w_lm"])}


# ---------------------------------------------------------------------------
# Parameter init.
# ---------------------------------------------------------------------------

def _norm_params(cfg, d):
    if cfg.norm == "rmsnorm":
        return {"g": jnp.ones((d,), jnp.float32)}
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def init_layer(key, spec: LayerSpec, cfg: ModelConfig, scale_out: float):
    d, hd = cfg.d_model, cfg.hd
    ks = iter(jax.random.split(key, 24))
    nrm = lambda *shape, s=0.02: (jax.random.normal(next(ks), shape,
                                                    jnp.float32) * s)
    p = {"ln1": _norm_params(cfg, d)}
    if spec.mixer == "attn":
        mix = {"wq": nrm(d, cfg.n_heads * hd), "wk": nrm(d, cfg.kv_heads * hd),
               "wv": nrm(d, cfg.kv_heads * hd),
               "wo": nrm(cfg.n_heads * hd, d, s=scale_out)}
        if spec.qk_norm:
            mix["qg"] = jnp.ones((hd,), jnp.float32)
            mix["kg"] = jnp.ones((hd,), jnp.float32)
    elif spec.mixer == "mamba":
        di, r, n, ck = ssm.mamba_dims(cfg)
        mix = {"w_in_x": nrm(d, di), "w_in_z": nrm(d, di),
               "w_out": nrm(di, d, s=scale_out),
               "core": {
                   "conv_w": nrm(di, ck, s=0.1),
                   "conv_b": jnp.zeros((di,), jnp.float32),
                   "w_x": nrm(di, r + 2 * n),
                   "w_dt": nrm(r, di, s=r ** -0.5),
                   "dt_bias": jnp.log(jnp.expm1(
                       jnp.full((di,), 0.01, jnp.float32))),
                   "A_log": jnp.log(jnp.tile(
                       jnp.arange(1, n + 1, dtype=jnp.float32), (di, 1))),
                   "D": jnp.ones((di,), jnp.float32)}}
    elif spec.mixer == "mlstm":
        du, nh, mhd = ssm.mlstm_dims(cfg)
        mix = {"w_upx": nrm(d, du), "w_upz": nrm(d, du),
               "wq": nrm(nh, mhd, mhd, s=mhd ** -0.5),
               "wk": nrm(nh, mhd, mhd, s=mhd ** -0.5),
               "wv": nrm(nh, mhd, mhd, s=mhd ** -0.5),
               "wi": nrm(nh, mhd, s=0.1), "wf": nrm(nh, mhd, s=0.1) + 3.0,
               "w_down": nrm(du, d, s=scale_out)}
    elif spec.mixer == "slstm":
        du, nh, shd = ssm.slstm_dims(cfg)
        mix = {"w_x": nrm(d, 4 * du),
               "core": {"r": nrm(4, nh, shd, shd, s=shd ** -0.5)},
               "w_down": nrm(du, d, s=scale_out)}
    else:
        raise ValueError(spec.mixer)
    p["mixer"] = mix
    if spec.mlp != "none":
        p["ln2"] = _norm_params(cfg, d)
        if spec.mlp == "moe":
            moe = cfg.moe
            E, f = moe.num_experts, moe.d_ff
            mlp = {"router": nrm(d, E),
                   "wg": nrm(E, d, f), "wd": nrm(E, f, d, s=scale_out)}
            if moe.gated:
                mlp["wu"] = nrm(E, d, f)
        elif spec.mlp == "gated":
            mlp = {"wg": nrm(d, cfg.d_ff), "wu": nrm(d, cfg.d_ff),
                   "wd": nrm(cfg.d_ff, d, s=scale_out)}
        else:
            mlp = {"w1": nrm(d, cfg.d_ff), "w2": nrm(cfg.d_ff, d, s=scale_out)}
        p["mlp"] = mlp
    return p


def init_params(key, cfg: ModelConfig):
    """Canonical (per-layer list, unstacked, full/unsharded) parameters."""
    n = cfg.n_layers
    keys = jax.random.split(key, n + 2)
    scale_out = 0.02 / max(1.0, (2 * n) ** 0.5)
    embed = {}
    if cfg.frontend == "text" or cfg.causal:
        embed["emb"] = jax.random.normal(keys[-1], (cfg.vocab, cfg.d_model),
                                         jnp.float32) * 0.02
    if cfg.frontend == "embed":
        embed["proj"] = jax.random.normal(keys[-2], (cfg.d_model, cfg.d_model),
                                          jnp.float32) * 0.02
    blocks = [init_layer(keys[i], cfg.layers[i], cfg, scale_out)
              for i in range(n)]
    head = {"ln_f": _norm_params(cfg, cfg.d_model),
            "w_lm": jax.random.normal(keys[-2], (cfg.d_model, cfg.vocab),
                                      jnp.float32) * 0.02}
    return {"embed": embed, "blocks": blocks, "head": head}


# ---------------------------------------------------------------------------
# Period detection & stacking for the pjit scan path.
# ---------------------------------------------------------------------------

def period_of(cfg: ModelConfig) -> int:
    n = cfg.n_layers
    for p in range(1, n + 1):
        if n % p == 0 and all(cfg.layers[i] == cfg.layers[i % p]
                              for i in range(n)):
            return p
    return n


def stack_blocks(blocks, period: int):
    """[per-layer dicts] -> [per-position-in-period dicts with leading reps]."""
    reps = len(blocks) // period
    out = []
    for pos in range(period):
        sl = [blocks[r * period + pos] for r in range(reps)]
        out.append(jax.tree.map(lambda *xs: jnp.stack(xs), *sl))
    return out


def unstack_blocks(stacked, period: int):
    reps = jax.tree_util.tree_leaves(stacked[0])[0].shape[0]
    blocks = []
    for r in range(reps):
        for pos in range(period):
            blocks.append(jax.tree.map(lambda x: x[r], stacked[pos]))
    return blocks


# ---------------------------------------------------------------------------
# pjit-path forward / loss.
# ---------------------------------------------------------------------------

def _rope_for(cfg: ModelConfig, seq: int, offset: int = 0):
    cos, sin = units.rope_tables(seq + offset, cfg.hd, cfg.rope_theta)
    return cos[offset:], sin[offset:]


def forward(params, batch, cfg: ModelConfig, *, remat: bool = False,
            tp: TPContext = TPContext()):
    """Full-model forward to final hidden states.  `params["blocks"]` must be
    the *stacked* form (see `stack_blocks`)."""
    period = period_of(cfg)
    specs = cfg.layers[:period]
    x, _ = embed_fwd(params["embed"], batch, cfg)
    seq = x.shape[1]
    rope = _rope_for(cfg, seq)

    def body(x, sliced):
        for pos in range(period):
            x, _ = layer_fwd(sliced[pos], tp, x, rope, specs[pos], cfg)
        return x, None

    if remat:
        body = jax.checkpoint(body)
    from repro.models import attention_core as AC
    reps = jax.tree_util.tree_leaves(params["blocks"])[0].shape[0]
    x, _ = jax.lax.scan(body, x, params["blocks"],
                        unroll=reps if AC._ANALYSIS["on"] else 1)
    return x


def loss_fn(params, batch, cfg: ModelConfig, *, remat: bool = False,
            tp: TPContext = TPContext()):
    x = forward(params, batch, cfg, remat=remat, tp=tp)
    loss, _ = head_fwd(params["head"], tp, x, batch["labels"], cfg)
    return loss


# ---------------------------------------------------------------------------
# Serving: per-layer decode step with caches, and prefill.
# ---------------------------------------------------------------------------

def init_layer_cache(spec: LayerSpec, cfg: ModelConfig, batch: int,
                     max_seq: int, dtype=jnp.bfloat16):
    """Attention layers hold a KV ring buffer: full ``max_seq`` slots for
    global layers, ``window`` slots for sliding-window layers (gemma3
    locals) — this is what makes windowed archs long-context-decodable.
    Each slot remembers its absolute position (-1 = empty) so masking stays
    exact after wraparound.  SSM mixers carry O(1) recurrent states."""
    if spec.mixer == "attn":
        slots = max_seq if spec.window is None else min(max_seq, spec.window)
        shape = (batch, cfg.kv_heads, slots, cfg.hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "pos": jnp.full((slots,), -1, jnp.int32)}
    if spec.mixer == "mamba":
        return ssm.mamba_init_state(cfg, batch, dtype=dtype)
    if spec.mixer == "mlstm":
        return ssm.mlstm_init_state(cfg, batch)
    if spec.mixer == "slstm":
        return ssm.slstm_init_state(cfg, batch)
    raise ValueError(spec.mixer)


def _attn_decode(params, tp, x_ln, x_res, cache, pos, spec, cfg):
    """One-token attention over the KV ring buffer.

    Single-query attention is linear in cache length, so it is expressed as
    plain (GSPMD-shardable) einsums with explicit fp32 softmax statistics —
    under pjit the cache shards along its slot axis across ``model`` (and
    ``data``) ranks and XLA inserts the max/sum all-reduces, i.e.
    distributed flash-decode falls out of the sharding annotations."""
    b = x_ln.shape[0]
    hd = cfg.hd
    q = jnp.einsum("bsd,df->bsf", x_ln, params["wq"])
    k = jnp.einsum("bsd,df->bsf", x_ln, params["wk"])
    v = jnp.einsum("bsd,df->bsf", x_ln, params["wv"])
    nh_l, kv_l = q.shape[-1] // hd, k.shape[-1] // hd
    qh = q.reshape(b, 1, nh_l, hd).transpose(0, 2, 1, 3)    # (b, h, 1, hd)
    kh = k.reshape(b, 1, kv_l, hd).transpose(0, 2, 1, 3)
    vh = v.reshape(b, 1, kv_l, hd).transpose(0, 2, 1, 3)
    if spec.qk_norm:
        qh = ag.rmsnorm(params["qg"], qh)
        kh = ag.rmsnorm(params["kg"], kh)
    if cfg.use_rope:
        cos, sin = units.rope_at(pos, hd, cfg.rope_theta)
        qh = units.apply_rope(qh, cos, sin)
        kh = units.apply_rope(kh, cos, sin)
    slots = cache["k"].shape[2]
    slot = pos % slots
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], kh.astype(cache["k"].dtype), slot, axis=2)
    cv = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], vh.astype(cache["v"].dtype), slot, axis=2)
    cpos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], pos[None].astype(jnp.int32), slot, axis=0)
    # GQA: fold q heads onto kv groups
    g = nh_l // kv_l
    qg = qh.reshape(b, kv_l, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bktd->bkgt", qg,
                   ck.astype(jnp.float32)) * hd ** -0.5   # (b, kv, g, T)
    ok = (cpos >= 0) & (cpos <= pos)
    if spec.window is not None:
        ok &= (pos - cpos) < spec.window
    s = jnp.where(ok[None, None, None, :], s, -1e30)
    m = jax.lax.stop_gradient(s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m)
    p = jnp.where(ok[None, None, None, :], p, 0.0)
    o = jnp.einsum("bkgt,bktd->bkgd", p, cv.astype(jnp.float32)) \
        / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    a = o.reshape(b, nh_l, 1, hd).transpose(0, 2, 1, 3) \
        .reshape(b, 1, nh_l * hd).astype(x_ln.dtype)
    part = jnp.einsum("bsd,df->bsf", a, params["wo"])
    y = tp.fuse_residual(part, x_res)
    return y, {"k": ck, "v": cv, "pos": cpos}


def decode_layer_step(params, tp: TPContext, x, cache, pos, spec: LayerSpec,
                      cfg: ModelConfig):
    """One-token step through one layer. x (b, 1, d)."""
    x_ln, _ = units.prenorm_fwd(params["ln1"], x, cfg)
    if spec.mixer == "attn":
        y1, new_cache = _attn_decode(params["mixer"], tp, x_ln, x, cache,
                                     pos, spec, cfg)
    elif spec.mixer == "mamba":
        y1, new_cache = ssm.mamba_step(params["mixer"], tp, x_ln, x, cache, cfg)
    elif spec.mixer == "mlstm":
        y1, new_cache = ssm.mlstm_step(params["mixer"], tp, x_ln, x, cache, cfg)
    elif spec.mixer == "slstm":
        y1, new_cache = ssm.slstm_step(params["mixer"], tp, x_ln, x, cache, cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.mlp == "none":
        return y1, new_cache
    x_ln2, _ = units.prenorm_fwd(params["ln2"], y1, cfg)
    if spec.mlp == "moe":
        y2 = moe_decode(params["mlp"], tp, x_ln2, y1, cfg)
    else:
        y2, _ = units.mlp_fwd(params["mlp"], tp, x_ln2, y1, spec, cfg)
    return y2, new_cache


def moe_decode(params, tp: TPContext, x_ln, x_res, cfg: ModelConfig):
    """Decode-path MoE: gather the top-k experts' weights per token instead of
    capacity dispatch — the true decode roofline is reading k experts' weights
    per token (memory-bound), not an (E × capacity) GEMM."""
    moe = cfg.moe
    b, s, d = x_ln.shape
    logits = jnp.einsum("bsd,de->bse", x_ln, params["router"])
    gates, idx = jax.lax.top_k(logits, moe.top_k)
    gates = jax.nn.softmax(gates.astype(jnp.float32), axis=-1)
    idx = idx.reshape(b * s, moe.top_k)
    xt = x_ln.reshape(b * s, d)
    wg = jnp.take(params["wg"], idx, axis=0)          # (T, k, d, f)
    wd = jnp.take(params["wd"], idx, axis=0)          # (T, k, f, d)
    if moe.gated:
        wu = jnp.take(params["wu"], idx, axis=0)
        h = jax.nn.silu(jnp.einsum("td,tkdf->tkf", xt, wg)) \
            * jnp.einsum("td,tkdf->tkf", xt, wu)
    else:
        h = jax.nn.gelu(jnp.einsum("td,tkdf->tkf", xt, wg))
    out = jnp.einsum("tkf,tkfd->tkd", h, wd)
    part = jnp.einsum("tkd,tk->td", out,
                      gates.reshape(b * s, moe.top_k).astype(out.dtype))
    part = part.reshape(b, s, d).astype(x_res.dtype)
    return tp.fuse_residual(part, x_res)


def init_caches_stacked(cfg: ModelConfig, batch: int, max_seq: int,
                        dtype=jnp.bfloat16):
    """Decode caches in the period-stacked layout used by the scan paths:
    list (period) of cache trees with a leading (reps,) dim."""
    period = period_of(cfg)
    reps = cfg.n_layers // period
    out = []
    for pos in range(period):
        one = init_layer_cache(cfg.layers[pos], cfg, batch, max_seq, dtype)
        out.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (reps,) + x.shape), one))
    return out


def decode_step(params, caches, batch, pos, cfg: ModelConfig,
                tp: TPContext = TPContext()):
    """One-token decode through the whole model (stacked params/caches).

    batch: {"tokens": (b, 1)} or {"embeds": (b, 1, d)}; pos: traced scalar.
    Returns (next_token (b,), logits (b, vocab), new_caches)."""
    period = period_of(cfg)
    specs = cfg.layers[:period]
    x, _ = embed_fwd(params["embed"], batch, cfg)
    new_caches = []
    for i in range(period):
        def body(x, pc, spec=specs[i]):
            lp, cache = pc
            y, nc = decode_layer_step(lp, tp, x, cache, pos, spec, cfg)
            return y, nc

        from repro.models import attention_core as AC
        reps = jax.tree_util.tree_leaves(params["blocks"][i])[0].shape[0]
        x, nc = jax.lax.scan(body, x, (params["blocks"][i], caches[i]),
                             unroll=reps if AC._ANALYSIS["on"] else 1)
        new_caches.append(nc)
    x_ln, _ = units.prenorm_fwd(params["head"]["ln_f"], x, cfg)
    logits = jnp.einsum("bsd,dv->bsv", x_ln, params["head"]["w_lm"])[:, 0]
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return nxt, logits, new_caches


def prefill_step(params, batch, cfg: ModelConfig,
                 tp: TPContext = TPContext()):
    """Inference prefill: full forward, last-position logits.  (KV-cache
    materialization shares the forward's cost profile; the lowered artifact
    omits the cache writes — noted in DESIGN.md §5.)"""
    x = forward(params, batch, cfg, remat=False, tp=tp)
    x_ln, _ = units.prenorm_fwd(params["head"]["ln_f"], x[:, -1:], cfg)
    logits = jnp.einsum("bsd,dv->bsv", x_ln, params["head"]["w_lm"])
    return logits[:, 0]


# ---------------------------------------------------------------------------
# Continuous-batching serve path: per-row positions + paged KV pool.
# The single-request helpers above share one position scalar across the
# batch; the entry points below are what `repro.serve.engine` drives — every
# row carries its own absolute position (-1 = inactive) and attention layers
# address a pool of fixed-size KV blocks through a per-row block table.
# ---------------------------------------------------------------------------

SSM_STEP = {"mamba": ssm.mamba_step, "mlstm": ssm.mlstm_step,
            "slstm": ssm.slstm_step}


def rope_rows(pos, hd: int, theta: float):
    """RoPE rows for per-request positions: pos (b,) -> cos/sin (b, hd/2).
    Same formula as ``units.rope_at`` so serve matches the decode oracle."""
    inv = 1.0 / theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)
    ang = pos.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope_rows(x, cos, sin):
    """x (b, h, 1, hd); cos/sin (b, hd/2) — per-row single-token rotation."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = cos[:, None, None, :]
    s = sin[:, None, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


def attn_ring_blocks(spec: LayerSpec, block_size: int, max_blocks: int) -> int:
    """Block-table width a layer actually addresses.  Global layers walk the
    full table; sliding-window layers reuse a ring of just enough blocks to
    cover ``window`` live positions plus the block being overwritten."""
    if spec.window is None:
        return max_blocks
    return min(max_blocks, -(-spec.window // block_size) + 1)


def attn_decode_paged(params, tp: TPContext, x_ln, x_res, pool, table, pos,
                      spec: LayerSpec, cfg: ModelConfig):
    """One-token attention against a paged KV pool.

    pool: {"k","v": (nb, kvh_local, bs, hd), "pos": (nb, bs)} — the physical
    block pool (pos holds absolute positions, -1 = empty slot).
    table (b, W) int32: per-row physical block ids; entries beyond a row's
    allocation (and every entry of an inactive row) point at the garbage
    block, whose slots stay masked.  pos (b,): the token's absolute
    position, -1 for inactive rows (their write lands in the garbage block
    and is recorded as empty).  Same softmax math as ``_attn_decode`` so the
    paged path matches the contiguous ring oracle token-for-token."""
    b = x_ln.shape[0]
    hd = cfg.hd
    bs = pool["k"].shape[2]
    q = jnp.einsum("bsd,df->bsf", x_ln, params["wq"])
    k = jnp.einsum("bsd,df->bsf", x_ln, params["wk"])
    v = jnp.einsum("bsd,df->bsf", x_ln, params["wv"])
    nh_l, kv_l = q.shape[-1] // hd, k.shape[-1] // hd
    qh = q.reshape(b, 1, nh_l, hd).transpose(0, 2, 1, 3)
    kh = k.reshape(b, 1, kv_l, hd).transpose(0, 2, 1, 3)
    vh = v.reshape(b, 1, kv_l, hd).transpose(0, 2, 1, 3)
    if spec.qk_norm:
        qh = ag.rmsnorm(params["qg"], qh)
        kh = ag.rmsnorm(params["kg"], kh)
    if cfg.use_rope:
        cos, sin = rope_rows(jnp.maximum(pos, 0), hd, cfg.rope_theta)
        qh = apply_rope_rows(qh, cos, sin)
        kh = apply_rope_rows(kh, cos, sin)
    ring = attn_ring_blocks(spec, bs, table.shape[1])
    tab = table[:, :ring]                                  # (b, R)
    p_eff = jnp.maximum(pos, 0)
    logical = (p_eff // bs) % ring
    blk = jnp.take_along_axis(tab, logical[:, None], axis=1)[:, 0]
    off = p_eff % bs
    ck = pool["k"].at[blk, :, off].set(kh[:, :, 0].astype(pool["k"].dtype))
    cv = pool["v"].at[blk, :, off].set(vh[:, :, 0].astype(pool["v"].dtype))
    cpos = pool["pos"].at[blk, off].set(pos.astype(jnp.int32))
    gk = ck[tab]                                           # (b, R, kvh, bs, hd)
    gv = cv[tab]
    T = ring * bs
    gk = gk.transpose(0, 2, 1, 3, 4).reshape(b, kv_l, T, hd)
    gv = gv.transpose(0, 2, 1, 3, 4).reshape(b, kv_l, T, hd)
    gpos = cpos[tab].reshape(b, T)
    g = nh_l // kv_l
    qg = qh.reshape(b, kv_l, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bktd->bkgt", qg,
                   gk.astype(jnp.float32)) * hd ** -0.5
    ok = (gpos >= 0) & (gpos <= pos[:, None])
    if spec.window is not None:
        ok &= (pos[:, None] - gpos) < spec.window
    okb = ok[:, None, None, :]
    s = jnp.where(okb, s, -1e30)
    m = jax.lax.stop_gradient(s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m)
    p = jnp.where(okb, p, 0.0)
    o = jnp.einsum("bkgt,bktd->bkgd", p, gv.astype(jnp.float32)) \
        / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    a = o.reshape(b, nh_l, 1, hd).transpose(0, 2, 1, 3) \
        .reshape(b, 1, nh_l * hd).astype(x_ln.dtype)
    part = jnp.einsum("bsd,df->bsf", a, params["wo"])
    y = tp.fuse_residual(part, x_res)
    return y, {"k": ck, "v": cv, "pos": cpos}


def _sel_rows(mask, new, old):
    m = mask.reshape(mask.shape + (1,) * (new.ndim - 1))
    return jnp.where(m, new.astype(old.dtype), old)


def decode_layer_paged(params, tp: TPContext, x, cache, table, pos, active,
                       spec: LayerSpec, cfg: ModelConfig):
    """Continuous-batching decode through one layer.  x (b, 1, d); pos (b,)
    per-row absolute positions; active (b,) bool.  Attention layers write
    through the paged pool (inactive rows land in the garbage block); SSM
    layers carry per-row state slots, frozen where inactive."""
    x_ln, _ = units.prenorm_fwd(params["ln1"], x, cfg)
    if spec.mixer == "attn":
        y1, new_cache = attn_decode_paged(params["mixer"], tp, x_ln, x,
                                          cache, table, pos, spec, cfg)
    else:
        y1, nc = SSM_STEP[spec.mixer](params["mixer"], tp, x_ln, x, cache,
                                      cfg)
        new_cache = jax.tree.map(lambda n, o: _sel_rows(active, n, o),
                                 nc, cache)
    if spec.mlp == "none":
        return y1, new_cache
    x_ln2, _ = units.prenorm_fwd(params["ln2"], y1, cfg)
    if spec.mlp == "moe":
        y2 = moe_decode(params["mlp"], tp, x_ln2, y1, cfg)
    else:
        y2, _ = units.mlp_fwd(params["mlp"], tp, x_ln2, y1, spec, cfg)
    return y2, new_cache


def prefill_layer(params, tp: TPContext, x, rope, lengths, spec: LayerSpec,
                  cfg: ModelConfig):
    """Whole-prompt prefill through one layer — ONE forward over the padded
    prompt, not a teacher-forced decode loop.  Attention runs full-sequence
    flash attention and extracts the rope'd/normed KV (``attn_prefill``);
    recurrent mixers replay their decode step under a single masked
    ``lax.scan`` (``ssm.prefill_scan``) so the handed-off state is exact.
    Returns (y (b, s, d), kv {"k","v"} (b, kvh, s, hd) | final ssm state)."""
    x_ln, _ = units.prenorm_fwd(params["ln1"], x, cfg)
    if spec.mixer == "attn":
        y1, kv = attn_prefill(params["mixer"], tp, x_ln, x, rope, spec, cfg)
    else:
        init = ssm.init_state_like(spec.mixer, params["mixer"], x.shape[0])
        y1, kv = ssm.prefill_scan(SSM_STEP[spec.mixer], params["mixer"], tp,
                                  x_ln, x, init, lengths, cfg)
    if spec.mlp == "none":
        return y1, kv
    x_ln2, _ = units.prenorm_fwd(params["ln2"], y1, cfg)
    if spec.mlp == "moe":
        y2 = moe_decode(params["mlp"], tp, x_ln2, y1, cfg)
    else:
        y2, _ = units.mlp_fwd(params["mlp"], tp, x_ln2, y1, spec, cfg)
    return y2, kv


def attn_prefill(params, tp, x_ln, x_res, rope, spec, cfg):
    """Forward with KV-cache extraction (inference prefill)."""
    cos, sin = rope
    b, s, _ = x_ln.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,df->bsf", x_ln, params["wq"])
    k = jnp.einsum("bsd,df->bsf", x_ln, params["wk"])
    v = jnp.einsum("bsd,df->bsf", x_ln, params["wv"])
    nh_l, kv_l = q.shape[-1] // hd, k.shape[-1] // hd
    qh = q.reshape(b, s, nh_l, hd).transpose(0, 2, 1, 3)
    kh = k.reshape(b, s, kv_l, hd).transpose(0, 2, 1, 3)
    vh = v.reshape(b, s, kv_l, hd).transpose(0, 2, 1, 3)
    if spec.qk_norm:
        qh = ag.rmsnorm(params["qg"], qh)
        kh = ag.rmsnorm(params["kg"], kh)
    if cfg.use_rope:
        qh = units.apply_rope(qh, cos, sin)
        kh = units.apply_rope(kh, cos, sin)
    o = flash_attention_inference(qh, kh, vh, cfg.causal, spec.window)
    a = o.transpose(0, 2, 1, 3).reshape(b, s, nh_l * hd)
    part = jnp.einsum("bsd,df->bsf", a, params["wo"])
    y = tp.fuse_residual(part, x_res)
    return y, {"k": kh, "v": vh}
