"""Memory-sane attention core: pure-JNP flash attention (chunked online
softmax) with a hand-rolled flash backward under ``jax.custom_vjp``.

This is the *model-path* attention for every architecture (the paper uses
FlashAttention-2 in all experiments, §5.1) and simultaneously the oracle the
Pallas kernel in ``repro.kernels`` is validated against.

Supports:
  * GQA (kv_heads <= q_heads) — q heads are folded into the row dimension of
    their kv group with an explicit position vector, so masks stay exact;
  * causal masking, sliding-window masking (gemma3 locals), full (encoder);
  * fp32 softmax accumulation regardless of input dtype.

Memory is O(block · T) per program instead of O(S·T): safe to lower at
seq_len = 524,288.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30

# Analysis mode (dry-run cost accounting): XLA's cost_analysis counts a
# while-loop body ONCE regardless of trip count, so the dry-run lowers an
# *unrolled* small-depth variant to get exact per-layer numbers.  Inside
# `unroll_for_analysis()` every chunk loop below is unrolled and the block
# sizes are enlarged to keep the op count bounded.
_ANALYSIS = {"on": False, "qb": 2048, "kb": 4096}


@contextlib.contextmanager
def unroll_for_analysis(qb: int = 2048, kb: int = 4096):
    old = dict(_ANALYSIS)
    _ANALYSIS.update(on=True, qb=qb, kb=kb)
    try:
        yield
    finally:
        _ANALYSIS.update(old)


def _unroll() -> bool:
    return _ANALYSIS["on"]


# Distribution hint: the collapsed (B*Hq) leading dim of the chunked
# attention should shard over (data..., model).  GSPMD's propagation gives
# up on the pad/reshape/moveaxis pipeline and replicates attention across
# the model axis (a silent 16x flop blowup at TP=16); an explicit
# with_sharding_constraint on the folded tensors pins it.  Set by the
# launch layer around lowering; unset (default) for single-device tests.
_BH_SHARD = {"axes": None}


@contextlib.contextmanager
def bh_sharding(axes):
    old = _BH_SHARD["axes"]
    _BH_SHARD["axes"] = axes
    try:
        yield
    finally:
        _BH_SHARD["axes"] = old


def _constrain_bh(x):
    axes = _BH_SHARD["axes"]
    if axes is None:
        return x
    spec = jax.sharding.PartitionSpec(axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


# Fast-math mode (§Perf, beyond-paper): keep the score/probability blocks
# in the input dtype and let the MXU accumulate in fp32
# (preferred_element_type) instead of materializing fp32 copies of q/k/p.
# Row statistics (max, logsumexp) stay fp32.  Off by default — tests and
# the paper-faithful baseline use full fp32 intermediates.
_FAST = {"on": False}


@contextlib.contextmanager
def fast_attention_math():
    old = _FAST["on"]
    _FAST["on"] = True
    try:
        yield
    finally:
        _FAST["on"] = old


def _qk(qblk, kblk):
    if _FAST["on"]:
        return jax.lax.dot_general(
            qblk, kblk, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
    return jnp.einsum("bnd,btd->bnt", qblk.astype(jnp.float32),
                      kblk.astype(jnp.float32))


def _pv(p, vblk):
    if _FAST["on"]:
        return jax.lax.dot_general(
            p.astype(vblk.dtype), vblk, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
    return jnp.einsum("bnt,btd->bnd", p, vblk.astype(jnp.float32))


def _scan(f, init, xs):
    return jax.lax.scan(f, init, xs, unroll=len(jax.tree_util.tree_leaves(
        xs)[0]) if _ANALYSIS["on"] else 1)


def _map(f, xs):
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]

    def step(_, x):
        return None, f(x)

    _, ys = jax.lax.scan(step, None, xs,
                         unroll=n if _ANALYSIS["on"] else 1)
    return ys


def _pad_to(x, mult, axis):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _mask(qpos, kpos, *, causal: bool, window: Optional[int], n_k: int):
    """(nq, nk) bool mask of *allowed* positions for one block pair."""
    m = kpos[None, :] < n_k
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= (qpos[:, None] - kpos[None, :]) < window
    return m


def _flash_fwd_impl(q, k, v, qpos, *, causal, window, scale, qb, kb):
    """q (BH, N, D) fp-any; k, v (BH, T, D); qpos (N,) int32.
    Returns o (BH, N, D), lse (BH, N) fp32."""
    BH, N, D = q.shape
    T = k.shape[1]
    qp = _pad_to(q, qb, 1)
    qpp = _pad_to(qpos, qb, 0)
    kp = _pad_to(k, kb, 1)
    vp = _pad_to(v, kb, 1)
    Np, Tp = qp.shape[1], kp.shape[1]
    nqb, nkb = Np // qb, Tp // kb
    kpos_full = jnp.arange(Tp, dtype=jnp.int32)

    qp = qp.reshape(BH, nqb, qb, D)
    qpp = qpp.reshape(nqb, qb)
    kblocks = kp.reshape(BH, nkb, kb, D)
    vblocks = vp.reshape(BH, nkb, kb, D)
    kposb = kpos_full.reshape(nkb, kb)

    def one_qblock(qblk, qposb):
        # qblk (BH, qb, D), qposb (qb,)
        def step(carry, inp):
            m, l, acc = carry
            kblk, vblk, kpos = inp
            s = _qk(qblk, kblk) * scale
            msk = _mask(qposb, kpos, causal=causal, window=window, n_k=T)
            s = jnp.where(msk[None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(msk[None], p, 0.0)
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + _pv(p, vblk)
            return (m_new, l, acc), None

        init = (jnp.full((BH, qb), NEG_INF, jnp.float32),
                jnp.zeros((BH, qb), jnp.float32),
                jnp.zeros((BH, qb, D), jnp.float32))
        (m, l, acc), _ = _scan(
            step, init,
            (jnp.moveaxis(kblocks, 1, 0), jnp.moveaxis(vblocks, 1, 0), kposb))
        l_safe = jnp.maximum(l, 1e-30)
        o = acc / l_safe[..., None]
        lse = m + jnp.log(l_safe)
        return o, lse

    o, lse = _map(lambda args: one_qblock(*args),
                  (jnp.moveaxis(qp, 1, 0), qpp))
    # o (nqb, BH, qb, D) -> (BH, N, D)
    o = jnp.moveaxis(o, 0, 1).reshape(BH, Np, D)[:, :N]
    lse = jnp.moveaxis(lse, 0, 1).reshape(BH, Np)[:, :N]
    return o, lse


def _flash_bwd_impl(q, k, v, qpos, o, lse, do, *, causal, window, scale, qb, kb):
    BH, N, D = q.shape
    T = k.shape[1]
    delta = jnp.einsum("bnd,bnd->bn", o.astype(jnp.float32),
                       do.astype(jnp.float32))  # (BH, N)

    qp = _pad_to(q, qb, 1).reshape(BH, -1, qb, D)
    dop = _pad_to(do, qb, 1).reshape(BH, -1, qb, D)
    lsep = _pad_to(lse, qb, 1).reshape(BH, -1, qb)
    deltap = _pad_to(delta, qb, 1).reshape(BH, -1, qb)
    qpp = _pad_to(qpos, qb, 0).reshape(-1, qb)
    kp = _pad_to(k, kb, 1).reshape(BH, -1, kb, D)
    vp = _pad_to(v, kb, 1).reshape(BH, -1, kb, D)
    Tp = kp.shape[1] * kb
    kposb = jnp.arange(Tp, dtype=jnp.int32).reshape(-1, kb)

    def p_block(qblk, qposb, lseb, kblk, kpos):
        s = _qk(qblk, kblk) * scale
        msk = _mask(qposb, kpos, causal=causal, window=window, n_k=T)
        p = jnp.exp(jnp.where(msk[None], s, NEG_INF) - lseb[..., None])
        return jnp.where(msk[None], p, 0.0)

    # --- dq: per q block, scan kv blocks -----------------------------------
    def dq_qblock(args):
        qblk, qposb, lseb, deltab, doblk = args

        def step(dq, inp):
            kblk, vblk, kpos = inp
            p = p_block(qblk, qposb, lseb, kblk, kpos)
            dp = _qk(doblk, vblk)
            ds = p * (dp - deltab[..., None])
            return dq + _pv(ds, kblk) * scale, None

        dq0 = jnp.zeros(qblk.shape, jnp.float32)
        dq, _ = _scan(step, dq0, (jnp.moveaxis(kp, 1, 0),
                                  jnp.moveaxis(vp, 1, 0), kposb))
        return dq

    dq = _map(dq_qblock, (jnp.moveaxis(qp, 1, 0), qpp,
                          jnp.moveaxis(lsep, 1, 0),
                          jnp.moveaxis(deltap, 1, 0),
                          jnp.moveaxis(dop, 1, 0)))
    dq = jnp.moveaxis(dq, 0, 1).reshape(BH, -1, D)[:, :N].astype(q.dtype)

    # --- dk, dv: per kv block, scan q blocks --------------------------------
    def dkv_kblock(args):
        kblk, vblk, kpos = args

        def step(carry, inp):
            dk, dv = carry
            qblk, qposb, lseb, deltab, doblk = inp
            p = p_block(qblk, qposb, lseb, kblk, kpos)
            dv = dv + _tp_pv(p, doblk)
            dp = _qk(doblk, vblk)
            ds = p * (dp - deltab[..., None])
            dk = dk + _tp_pv(ds, qblk) * scale
            return (dk, dv), None

        z = jnp.zeros(kblk.shape, jnp.float32)
        (dk, dv), _ = _scan(
            step, (z, z),
            (jnp.moveaxis(qp, 1, 0), qpp, jnp.moveaxis(lsep, 1, 0),
             jnp.moveaxis(deltap, 1, 0), jnp.moveaxis(dop, 1, 0)))
        return dk, dv

    dk, dv = _map(dkv_kblock, (jnp.moveaxis(kp, 1, 0),
                               jnp.moveaxis(vp, 1, 0), kposb))
    dk = jnp.moveaxis(dk, 0, 1).reshape(BH, -1, D)[:, :T].astype(k.dtype)
    dv = jnp.moveaxis(dv, 0, 1).reshape(BH, -1, D)[:, :T].astype(v.dtype)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# Public GQA entry point.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(q, k, v, causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None, q_offset: int = 0,
                    qb: int = 256, kb: int = 512):
    """q (B, Hq, S, D); k, v (B, Hkv, T, D) with Hq % Hkv == 0.

    ``q_offset``: absolute position of q[..., 0, :] relative to k (decode with
    a KV cache passes T - S)."""
    o, _ = _flash_gqa_fwd(q, k, v, causal, window, scale, q_offset, qb, kb)
    return o


def _expand_gqa(q, k, v):
    """GQA by kv broadcast to Hq heads, collapsed to (B*Hq, ., D).

    The (B, Hq) merge keeps a GSPMD-expressible sharding (batch over data x
    heads over model); the earlier fold to (B*Hkv, G*S, D) could NOT shard
    16 ways when Hkv < 16, which silently replicated all attention compute
    across the model axis (a 16x flop bug caught by the dry-run roofline)."""
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = _constrain_bh(q.reshape(B * Hq, S, D))
    kf = _constrain_bh(jnp.broadcast_to(k[:, :, None], (B, Hkv, G, T, D))
                       .reshape(B * Hq, T, D))
    vf = _constrain_bh(jnp.broadcast_to(v[:, :, None], (B, Hkv, G, T, D))
                       .reshape(B * Hq, T, D))
    return qf, kf, vf, (B, Hq, Hkv, G, S, D)


def _flash_gqa_fwd(q, k, v, causal, window, scale, q_offset, qb, kb):
    if _ANALYSIS["on"]:
        qb, kb = _ANALYSIS["qb"], _ANALYSIS["kb"]
    qf, kf, vf, dims = _expand_gqa(q, k, v)
    B, Hq, Hkv, G, S, D = dims
    scale = scale if scale is not None else D ** -0.5
    qpos = jnp.arange(S, dtype=jnp.int32) + q_offset
    o, lse = _flash_fwd_impl(qf, kf, vf, qpos, causal=causal, window=window,
                             scale=scale, qb=min(qb, max(S, 16)), kb=kb)
    o = _constrain_bh(o).reshape(B, Hq, S, D).astype(q.dtype)
    # o rides in the residuals: under layer-remat it is recomputed by the
    # rematted forward anyway, and the backward rule then skips a third
    # full attention pass (one of the §Perf hillclimb wins).
    return o, (q, k, v, o, lse)


def _flash_gqa_fwd_rule(q, k, v, causal, window, scale, q_offset, qb, kb):
    o, res = _flash_gqa_fwd(q, k, v, causal, window, scale, q_offset, qb, kb)
    return o, res


def _flash_gqa_bwd_rule(causal, window, scale, q_offset, qb, kb, res, do):
    if _ANALYSIS["on"]:
        qb, kb = _ANALYSIS["qb"], _ANALYSIS["kb"]
    q, k, v, o, lse = res
    qf, kf, vf, dims = _expand_gqa(q, k, v)
    B, Hq, Hkv, G, S, D = dims
    scale = scale if scale is not None else D ** -0.5
    qpos = jnp.arange(S, dtype=jnp.int32) + q_offset
    dof = _constrain_bh(do.reshape(B * Hq, S, D))
    lse = _constrain_bh(lse)
    of = _constrain_bh(o.reshape(B * Hq, S, D))
    dq, dk, dv = _flash_bwd_impl(qf, kf, vf, qpos, of, lse, dof,
                                 causal=causal, window=window, scale=scale,
                                 qb=min(qb, max(S, 16)), kb=kb)
    dq = _constrain_bh(dq).reshape(B, Hq, S, D)
    dk = _constrain_bh(dk).reshape(B, Hkv, G, -1, D).sum(axis=2) \
        .astype(k.dtype)
    dv = _constrain_bh(dv).reshape(B, Hkv, G, -1, D).sum(axis=2) \
        .astype(v.dtype)
    return dq, dk, dv


flash_attention.defvjp(_flash_gqa_fwd_rule, _flash_gqa_bwd_rule)


def flash_attention_inference(q, k, v, causal=True, window=None, scale=None,
                              q_offset=0, qb: int = 256, kb: int = 512):
    """Forward-only path for decode/prefill: ``q_offset`` may be a *traced*
    position scalar (custom_vjp nondiff args must be static, so the decode
    paths with a dynamic KV-cache offset use this entry point)."""
    o, _ = _flash_gqa_fwd(q, k, v, causal, window, scale, q_offset, qb, kb)
    return o


def reference_attention(q, k, v, causal: bool = True,
                        window: Optional[int] = None,
                        scale: Optional[float] = None, q_offset: int = 0):
    """Naive O(S·T) oracle used in tests only."""
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    qpos = jnp.arange(S) + q_offset
    kpos = jnp.arange(T)
    m = jnp.ones((S, T), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= (qpos[:, None] - kpos[None, :]) < window
    s = jnp.where(m[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, vv.astype(jnp.float32)).astype(q.dtype)

def _tp_pv(p, blk):
    """transposed pv: (b, n, t) x (b, n, d) -> (b, t, d)."""
    import jax, jax.numpy as jnp
    if _FAST["on"]:
        return jax.lax.dot_general(
            p.astype(blk.dtype), blk, (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
    return jnp.einsum("bnt,bnd->btd", p, blk.astype(jnp.float32))
