"""Fine-grained computation units (paper §3).

Each Transformer layer decomposes into **Pre-Attn**, **Attn**, **Pre-MLP**,
**MLP** units (SSM/hybrid archs swap the mixer unit; MoE swaps the MLP unit).
Every unit exposes

  ``*_fwd(params, tp, ...) -> (out, ctx)``
  ``*_bwd_act(params, tp, ctx, gy) -> (input grads..., wtape, joint_grads)``
  ``*_bwd_weight(wtape) -> deferred weight grads``

matching the paper's F / B / W decomposition: B propagates activation
gradients (and computes the <1%-FLOPs "core" parameter grads jointly, as
production Zero-Bubble implementations do), W holds the big ``dW = x^T g``
GEMMs on a *weight tape* for deferred execution.  All collectives are placed
per Fig. 2: the unit-output All-Reduce (``g`` operator, with Eq. (1) residual
fusion) in forward, the post-projection-input All-Reduce (``f`` operator) in
backward.  W computations are collective-free — which is exactly why the
schedule can use them to fill pipeline bubbles.

Everything is a pure function of pytrees — jittable and carryable through
``lax.scan`` / ``lax.switch`` in the pipeline executor.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import autograd as ag
from repro.models.attention_core import flash_attention
from repro.models.config import LayerSpec, ModelConfig
from repro.tp.context import TPContext


# ---------------------------------------------------------------------------
# RoPE tables
# ---------------------------------------------------------------------------

def rope_tables(max_seq: int, hd: int, theta: float):
    inv = 1.0 / theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)
    t = jnp.arange(max_seq, dtype=jnp.float32)
    ang = jnp.outer(t, inv)                       # (S, hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def rope_at(pos, hd: int, theta: float):
    """RoPE table row for a (possibly traced) scalar position: (1, hd/2)."""
    inv = 1.0 / theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)
    ang = jnp.asarray(pos, jnp.float32)[None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (b, h, s, hd); cos/sin (s, hd/2). NeoX-style half rotation.
    fp32 rotation, result cast back to x.dtype (keeps bf16 scan carries)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = cos[None, None, :, :]
    s = sin[None, None, :, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Pre-norm units (Pre-Attn / Pre-MLP).
# ---------------------------------------------------------------------------

def _norm_core(cfg: ModelConfig):
    if cfg.norm == "rmsnorm":
        return lambda p, x: ag.rmsnorm(p["g"], x)
    return lambda p, x: ag.layernorm((p["g"], p["b"]), x)


def prenorm_fwd(params, x, cfg: ModelConfig):
    core = _norm_core(cfg)
    y, saved = ag.core_vjp(core, params, x)
    return y, saved


def prenorm_bwd(ctx, g_ln, cfg: ModelConfig):
    core = _norm_core(cfg)
    pgrads, (gx,) = ag.core_bwd(core, ctx, g_ln)
    return gx, pgrads


# ---------------------------------------------------------------------------
# Attention unit.
# ---------------------------------------------------------------------------

def _attn_core_fn(spec: LayerSpec, cfg: ModelConfig, n_heads_local: int,
                  kv_heads_local: int, q_offset: int = 0):
    hd = cfg.hd

    def core(core_params, q, k, v, cos, sin):
        b, s, _ = q.shape
        qh = q.reshape(b, s, n_heads_local, hd).transpose(0, 2, 1, 3)
        kh = k.reshape(b, s, kv_heads_local, hd).transpose(0, 2, 1, 3)
        vh = v.reshape(b, s, kv_heads_local, hd).transpose(0, 2, 1, 3)
        if spec.qk_norm:
            qh = ag.rmsnorm(core_params["qg"], qh)
            kh = ag.rmsnorm(core_params["kg"], kh)
        if cfg.use_rope:
            qh = apply_rope(qh, cos, sin)
            kh = apply_rope(kh, cos, sin)
        o = flash_attention(qh, kh, vh, cfg.causal, spec.window, None, q_offset)
        return o.transpose(0, 2, 1, 3).reshape(b, s, n_heads_local * hd)

    return core


def attn_fwd(params, tp: TPContext, x_ln, x_res, rope, spec: LayerSpec,
             cfg: ModelConfig):
    cos, sin = rope
    q, _ = ag.linear_fwd(x_ln, params["wq"])
    k, _ = ag.linear_fwd(x_ln, params["wk"])
    v, _ = ag.linear_fwd(x_ln, params["wv"])
    nh_l = q.shape[-1] // cfg.hd
    kv_l = k.shape[-1] // cfg.hd
    core = _attn_core_fn(spec, cfg, nh_l, kv_l)
    core_params = {k_: params[k_] for k_ in ("qg", "kg") if k_ in params}
    a, core_saved = ag.core_vjp(core, core_params, q, k, v, cos, sin)
    o_part, _ = ag.linear_fwd(a, params["wo"])
    y = tp.fuse_residual(o_part, x_res)
    return y, (x_ln, core_saved, a)


def attn_bwd_act(params, tp: TPContext, ctx, gy, spec: LayerSpec,
                 cfg: ModelConfig):
    x_ln, core_saved, a = ctx
    nh_l = params["wq"].shape[-1] // cfg.hd
    kv_l = params["wk"].shape[-1] // cfg.hd
    core = _attn_core_fn(spec, cfg, nh_l, kv_l)
    g_res = gy                                     # Eq. (2) "+1" term
    g_a = ag.linear_bwd_act(gy, params["wo"])
    core_pgrads, (gq, gk, gv, _, _) = ag.core_bwd(core, core_saved, g_a)
    gx_ln = tp.psum_out(ag.linear_bwd_act(gq, params["wq"])
                        + ag.linear_bwd_act(gk, params["wk"])
                        + ag.linear_bwd_act(gv, params["wv"]))
    joint = {k_: tp.psum(v_) for k_, v_ in core_pgrads.items()}
    wtape = {"wq": ag.tape_entry(x_ln, gq), "wk": ag.tape_entry(x_ln, gk),
             "wv": ag.tape_entry(x_ln, gv), "wo": ag.tape_entry(a, gy)}
    return gx_ln, g_res, wtape, joint


def attn_bwd_weight(wtape):
    return {k: ag.tape_weight(e) for k, e in wtape.items()}


# ---------------------------------------------------------------------------
# Dense MLP units (gated / plain).
# ---------------------------------------------------------------------------

def _act_fn(name: str):
    return {"gelu": jax.nn.gelu, "silu": jax.nn.silu, "relu": jax.nn.relu}[name]


def mlp_fwd(params, tp: TPContext, x_ln, x_res, spec: LayerSpec,
            cfg: ModelConfig):
    if spec.mlp == "gated":
        hg, _ = ag.linear_fwd(x_ln, params["wg"])
        hu, _ = ag.linear_fwd(x_ln, params["wu"])
        act = _act_fn(cfg.gated_act)
        core = lambda _, g_, u_: act(g_) * u_
        a, core_saved = ag.core_vjp(core, None, hg, hu)
        part, _ = ag.linear_fwd(a, params["wd"])
        y = tp.fuse_residual(part, x_res)
        return y, (x_ln, core_saved, a)
    else:  # plain
        h1, _ = ag.linear_fwd(x_ln, params["w1"])
        act = _act_fn(cfg.plain_act)
        core = lambda _, h_: act(h_)
        a, core_saved = ag.core_vjp(core, None, h1)
        part, _ = ag.linear_fwd(a, params["w2"])
        y = tp.fuse_residual(part, x_res)
        return y, (x_ln, core_saved, a)


def mlp_bwd_act(params, tp: TPContext, ctx, gy, spec: LayerSpec,
                cfg: ModelConfig):
    x_ln, core_saved, a = ctx
    g_res = gy
    if spec.mlp == "gated":
        g_a = ag.linear_bwd_act(gy, params["wd"])
        act = _act_fn(cfg.gated_act)
        core = lambda _, g_, u_: act(g_) * u_
        _, (g_hg, g_hu) = ag.core_bwd(core, core_saved, g_a)
        gx_ln = tp.psum_out(ag.linear_bwd_act(g_hg, params["wg"])
                            + ag.linear_bwd_act(g_hu, params["wu"]))
        wtape = {"wg": ag.tape_entry(x_ln, g_hg), "wu": ag.tape_entry(x_ln, g_hu),
                 "wd": ag.tape_entry(a, gy)}
    else:
        g_a = ag.linear_bwd_act(gy, params["w2"])
        act = _act_fn(cfg.plain_act)
        core = lambda _, h_: act(h_)
        _, (g_h1,) = ag.core_bwd(core, core_saved, g_a)
        gx_ln = tp.psum_out(ag.linear_bwd_act(g_h1, params["w1"]))
        wtape = {"w1": ag.tape_entry(x_ln, g_h1), "w2": ag.tape_entry(a, gy)}
    return gx_ln, g_res, wtape, {}


def mlp_bwd_weight(wtape):
    return {k: ag.tape_weight(e) for k, e in wtape.items()}


# ---------------------------------------------------------------------------
# MoE MLP unit (capacity-dispatch, GShard/Megatron style; experts TP-sharded
# on their hidden dim like a dense MLP — router & dispatch replicated in the
# TP group, token dim sharded only across data parallel).
# ---------------------------------------------------------------------------

# Expert-parallel hint (§Perf): with experts sharded over `model`, GSPMD
# resolves the capacity-dispatch scatter by all-gathering the full
# (b, E, C, d) buffer unless the target sharding is pinned here.  Set by
# the launch layer; None (default) for single-device tests.
_MOE_SHARD = {"axes": None}     # (batch_axes, expert_axis)


def _constrain_moe(x, edim: int):
    axes = _MOE_SHARD["axes"]
    if axes is None:
        return x
    batch_axes, expert_axis = axes
    spec = [None] * x.ndim
    spec[0] = batch_axes
    spec[edim] = expert_axis
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*spec))


def moe_capacity(s_tokens: int, moe) -> int:
    return max(1, int(-(-moe.top_k * s_tokens * moe.capacity_factor
                        // moe.num_experts)))


def _route(logits, top_k: int, capacity: int):
    """Static (non-differentiable) routing decisions.

    logits (b, s, E) -> idx (b, s, k) int32, pos (b, s, k) int32 position in
    the expert's capacity buffer, keep (b, s, k) f32 in {0,1}."""
    b, s, E = logits.shape
    _, idx = jax.lax.top_k(logits, top_k)                   # (b, s, k)
    flat = idx.reshape(b, s * top_k)
    onehot = jax.nn.one_hot(flat, E, dtype=jnp.int32)       # (b, s*k, E)
    pos_all = jnp.cumsum(onehot, axis=1) - 1                # position per slot
    pos = jnp.take_along_axis(pos_all, flat[..., None], axis=-1)[..., 0]
    pos = pos.reshape(b, s, top_k)
    keep = (pos < capacity).astype(jnp.float32)
    pos = jnp.minimum(pos, capacity - 1)
    return idx, pos, keep


def _gates_core(logits, idx):
    """Differentiable part of routing: softmax over the selected top-k."""
    sel = jnp.take_along_axis(logits, idx, axis=-1)         # (b, s, k)
    return jax.nn.softmax(sel.astype(jnp.float32), axis=-1).astype(logits.dtype)


_RAGGED_DISPATCH = {"on": False}


def set_ragged_dispatch(enabled: bool) -> None:
    """Route the forward token dispatch through the Pallas ragged-dispatch
    kernel (``kernels.ragged_dispatch``) instead of the XLA scatter-add.

    Forward-only: the backward re-dispatch of per-slot grads relies on
    add-semantics for capacity-clamped (zero-valued) dropped slots, which
    the one-owner-per-slot gather does not model."""
    _RAGGED_DISPATCH["on"] = bool(enabled)


def _dispatch_tokens(x, idx, pos, keep, E, C):
    """Forward token dispatch: kernel gather when enabled, else dense."""
    if _RAGGED_DISPATCH["on"]:
        from repro.kernels import ops as kops
        return kops.ragged_dispatch(x, idx, pos, keep, E, C)
    return _dispatch(x, idx, pos, keep, E, C)


def _dispatch(x, idx, pos, keep, E, C):
    """x (b, s, d) -> expert_in (b, E, C, d) via scatter-add."""
    b, s, d = x.shape
    k = idx.shape[-1]
    flat = (idx * C + pos).reshape(b, s * k)
    upd = (x[:, :, None, :] * keep[..., None].astype(x.dtype)) \
        .reshape(b, s * k, d).astype(x.dtype)

    def one(fl, up):
        return jnp.zeros((E * C, d), x.dtype).at[fl].add(up)

    out = jax.vmap(one)(flat, upd)
    return out.reshape(b, E, C, d)


def _gather_combine(expert_out, idx, pos, keep, gates):
    """expert_out (b, E, C, d) -> (b, s, d) weighted combine."""
    b, E, C, d = expert_out.shape
    s, k = idx.shape[1], idx.shape[2]
    flat = (idx * C + pos).reshape(b, s * k)
    eo = expert_out.reshape(b, E * C, d)
    picked = jax.vmap(lambda e_, f_: e_[f_])(eo, flat).reshape(b, s, k, d)
    w = (gates * keep.astype(gates.dtype)).astype(expert_out.dtype)
    return jnp.einsum("bskd,bsk->bsd", picked, w), picked


def moe_fwd(params, tp: TPContext, x_ln, x_res, spec: LayerSpec,
            cfg: ModelConfig):
    moe = cfg.moe
    b, s, d = x_ln.shape
    C = moe_capacity(s, moe)
    logits, _ = ag.linear_fwd(x_ln, params["router"])
    idx, pos, keep = _route(jax.lax.stop_gradient(logits), moe.top_k, C)
    gates, gates_saved = ag.core_vjp(lambda _, lg: _gates_core(lg, idx),
                                     None, logits)
    expert_in = _constrain_moe(
        _dispatch_tokens(x_ln, idx, pos, keep, moe.num_experts, C), 1)
    # Expert parallelism: routing + dispatch above are replicated across the
    # expert axis (drops bitwise-identical to EP=1); each rank runs the FFN
    # on its contiguous E/ep expert slice against its local weight shards,
    # then the combine input is rebuilt by an expert-dim all-gather.
    ein = tp.ep_slice(expert_in, 1)
    if moe.gated:
        hg = jnp.einsum("becd,edf->becf", ein, params["wg"])
        hu = jnp.einsum("becd,edf->becf", ein, params["wu"])
        core = lambda _, g_, u_: jax.nn.silu(g_) * u_
        a, core_saved = ag.core_vjp(core, None, hg, hu)
    else:
        h1 = jnp.einsum("becd,edf->becf", ein, params["wg"])
        core = lambda _, h_: jax.nn.gelu(h_)
        a, core_saved = ag.core_vjp(core, None, h1)
    part = jnp.einsum("becf,efd->becd", a, params["wd"])
    expert_out = _constrain_moe(tp.ep_all_gather(tp.psum(part), 1), 1)
    y_moe, picked = _gather_combine(expert_out, idx, pos, keep, gates)
    y = y_moe + x_res
    ctx = (x_ln, gates_saved, (idx, pos, keep, gates), ein, core_saved,
           a, expert_out)
    return y, ctx


def moe_bwd_act(params, tp: TPContext, ctx, gy, spec: LayerSpec,
                cfg: ModelConfig):
    moe = cfg.moe
    x_ln, gates_saved, (idx, pos, keep, gates), expert_in, core_saved, a, \
        expert_out = ctx
    b, s, d = x_ln.shape
    E = moe.num_experts
    C = expert_out.shape[2]
    g_res = gy
    # combine bwd
    _, picked = _gather_combine(expert_out, idx, pos, keep, gates)
    g_gates = jnp.einsum("bsd,bskd->bsk", gy, picked) * keep
    g_picked = gy[:, :, None, :] * (gates * keep)[..., None]      # (b,s,k,d)
    g_expert_out = _dispatch(g_picked.reshape(b, s * idx.shape[-1], d)
                             .reshape(b, -1, d),
                             idx.reshape(b, -1, 1), pos.reshape(b, -1, 1),
                             jnp.ones_like(keep).reshape(b, -1, 1), E, C)
    g_expert_out = g_expert_out.reshape(b, E, C, d)
    # expert MLP bwd on this rank's expert slice (weight tapes stay local —
    # their grads shard exactly like the expert weight shards); the token
    # grad is rebuilt full by the expert-dim all-gather mirroring forward.
    g_eo = tp.ep_slice(g_expert_out, 1)
    if moe.gated:
        g_a = jnp.einsum("becd,efd->becf", g_eo, params["wd"])
        core = lambda _, g_, u_: jax.nn.silu(g_) * u_
        _, (g_hg, g_hu) = ag.core_bwd(core, core_saved, g_a)
        g_ein = tp.ep_all_gather(
            tp.psum(jnp.einsum("becf,edf->becd", g_hg, params["wg"])
                    + jnp.einsum("becf,edf->becd", g_hu, params["wu"])), 1)
        wtape = {"wg": (expert_in, g_hg), "wu": (expert_in, g_hu),
                 "wd": (a, g_eo)}
    else:
        g_a = jnp.einsum("becd,efd->becf", g_eo, params["wd"])
        core = lambda _, h_: jax.nn.gelu(h_)
        _, (g_h1,) = ag.core_bwd(core, core_saved, g_a)
        g_ein = tp.ep_all_gather(
            tp.psum(jnp.einsum("becf,edf->becd", g_h1, params["wg"])), 1)
        wtape = {"wg": (expert_in, g_h1), "wd": (a, g_eo)}
    # dispatch bwd: gather g_ein back to tokens
    k = idx.shape[-1]
    flat = (idx * C + pos).reshape(b, s * k)
    gtok = jax.vmap(lambda e_, f_: e_[f_])(g_ein.reshape(b, E * C, d), flat)
    gx_dispatch = jnp.einsum("bskd,bsk->bsd",
                             gtok.reshape(b, s, k, d), keep)
    # router bwd
    _, (g_logits,) = ag.core_bwd(lambda _, lg: _gates_core(lg, idx),
                                 gates_saved, g_gates)
    gx_router = ag.linear_bwd_act(g_logits, params["router"])
    gx_ln = gx_dispatch + gx_router
    wtape["router"] = ag.tape_entry(x_ln, g_logits)
    return gx_ln, g_res, wtape, {}


def moe_bwd_weight(wtape):
    out = {}
    for name, (x, g) in wtape.items():
        if name == "router":
            out[name] = ag.linear_bwd_weight(x, g)
        else:
            # (b, E, C, *) tapes: contract batch+capacity per expert
            out[name] = jnp.einsum(
                "becd,becf->edf", x, g,
                preferred_element_type=jnp.float32).astype(g.dtype)
    return out


def moe_aux_loss(logits, idx, moe) -> jnp.ndarray:
    """Switch-style load-balance auxiliary loss (used by pjit-mode training)."""
    E = moe.num_experts
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = probs.mean(axis=(0, 1))
    ce = jax.nn.one_hot(idx, E).sum(axis=2).mean(axis=(0, 1))
    return moe.aux_loss_coef * E * jnp.sum(me * ce)
