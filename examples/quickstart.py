"""Quickstart: the paper's braided F/B/W schedule in five minutes.

Builds a reduced qwen3-family model, runs
  (a) a monolithic jax.grad train step,
  (b) the same global batch through the STP braided pipeline schedule,
and checks they produce the same loss and gradients, then takes one
optimizer step with each.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.schedule import build, run as simulate_schedule
from repro.core.simulator import StageTimes
from repro.data import DataConfig, make_batches, microbatches
from repro.models import model as M
from repro.optim import OptConfig, adamw_init, adamw_update
from repro.pipeline.reference import pipeline_grads, reference_grads


def main():
    cfg = get_config("qwen3-4b").reduced(n_layers=4, d_model=128,
                                         n_heads=4, vocab=512)
    print(f"arch: {cfg.name}  layers={cfg.n_layers} d={cfg.d_model}")
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    dc = DataConfig(seq_len=64, global_batch=8)
    batch = {k: jnp.asarray(v)
             for k, v in next(make_batches(cfg, dc, 1)).items()}
    mbs = microbatches(batch, 4)

    # (a) monolithic
    loss_ref, g_ref = reference_grads(params, mbs, cfg)
    print(f"monolithic jax.grad loss: {float(loss_ref):.4f}")

    # (b) STP braided pipeline (2 stages, 2 chunks/stage, 4 microbatches)
    tables, pl = build("stp", 2, 4)
    loss_stp, g_stp = pipeline_grads(params, mbs, tables, pl, cfg)
    print(f"STP pipeline loss:        {float(loss_stp):.4f}")

    err = max(float(np.max(np.abs(a - b)))
              for a, b in zip(jax.tree.leaves(g_stp),
                              jax.tree.leaves(g_ref)))
    print(f"max grad diff: {err:.2e}  (braided F/B/W == autodiff)")

    # optimizer step
    oc = OptConfig(total_steps=10, warmup_steps=1)
    opt = adamw_init(params)
    params2, opt, gn = adamw_update(params, g_stp, opt, oc)
    print(f"adamw step done, grad norm {float(gn):.3f}")

    # what the schedule looks like at production scale
    res, _, _ = simulate_schedule("stp", 4, 64,
                                  StageTimes.uniform(8, t_ar=0.76))
    s = res.summary()
    print(f"simulated STP @ p=4, m=64: iteration {s['total_time']:.0f}u, "
          f"exposed TP comm {s['tp_exposed_mean']:.1f}u/device, "
          f"peak act {s['peak_mem_max']:.0f} Ma")


if __name__ == "__main__":
    main()
