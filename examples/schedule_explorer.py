"""Schedule explorer: ASCII timelines + bubble/memory stats for every
schedule the framework implements (the paper's Fig. 12 view).

  PYTHONPATH=src python examples/schedule_explorer.py --p 4 --m 8
"""
import argparse

from repro.core.schedule import SCHEDULES, run
from repro.core.simulator import StageTimes

GLYPH = {"F": "F", "B": "B", "W": "w", "BW": "B", "FB": "X", "FBW": "X",
         "FW": "f", "BWx": "b"}


def timeline(res, width=110):
    total = res.total_time
    lanes = {}
    for d, start, end, ins in res.trace:
        lane = lanes.setdefault(d, [" "] * width)
        a = int(start / total * (width - 1))
        b = max(a + 1, int(end / total * (width - 1)))
        g = GLYPH.get(ins.kind, "?")
        for i in range(a, min(b, width)):
            lane[i] = g
    return lanes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--p", type=int, default=4)
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--t-ar", type=float, default=0.5)
    args = ap.parse_args()

    print(f"p={args.p} devices, m={args.m} microbatches, "
          f"T_AR={args.t_ar} (glyphs: F fwd, B bwd, w weight-grad, "
          f"X braided F&B, f F&W, b B&W-stored)\n")
    for kind in SCHEDULES:
        n_vs = args.p if kind in ("gpipe", "1f1b") else 2 * args.p
        times = StageTimes.uniform(n_vs, t_ar=args.t_ar)
        res, _, _ = run(kind, args.p, args.m, times)
        s = res.summary()
        print(f"== {kind:11s} total={s['total_time']:7.1f}  "
              f"pp_bubble={s['pp_bubble_mean']:5.1f}  "
              f"tp_exposed={s['tp_exposed_mean']:5.1f}  "
              f"peak_mem={s['peak_mem_max']:4.1f} Ma")
        for d, lane in sorted(timeline(res).items()):
            print(f"  dev{d} |{''.join(lane)}|")
        print()


if __name__ == "__main__":
    main()
