"""Continuous-batching serving across three architecture families: the
``repro.serve.Engine`` holds params + paged KV / SSM-state pools mesh-resident
and streams requests through a batched prefill and per-tick decode.

  PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serve import Engine, EngineConfig


def main():
    for arch in ("qwen3-4b", "gemma3-12b", "xlstm-125m"):
        cfg = get_config(arch).reduced(n_layers=2, d_model=128, n_heads=4,
                                       vocab=512)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        b, plen, gen = 4, 16, 12
        prompts = np.asarray(
            jax.random.randint(jax.random.PRNGKey(1), (b, plen), 0,
                               cfg.vocab), np.int32)
        eng = Engine(cfg, params, EngineConfig(
            rows=4, blocks=32, block_size=8, max_seq=64, prefill_group=2))
        # Warmup compiles prefill+decode, then measure a clean batch.
        eng.generate([prompts[0]], 2)
        eng.reset_metrics()
        outs = eng.generate(list(prompts), gen)
        s = eng.metrics.summary()
        assert all(o.shape == (plen + gen,) for o in outs)
        kinds = {l.mixer for l in cfg.layers}
        print(f"{arch:28s} mixers={sorted(kinds)} "
              f"{s['completed']} reqs {s['gen_tokens']} tokens "
              f"{s['tokens_per_s']:7.1f} tok/s "
              f"ttft p50 {s['ttft_ms']['p50']:6.1f}ms "
              f"sample={list(outs[0][-6:])}")


if __name__ == "__main__":
    main()
