"""Batched serving: prefill + autoregressive decode with KV ring buffers /
SSM states across three architecture families.

  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import model as M


def main():
    for arch in ("qwen3-4b", "gemma3-12b", "xlstm-125m"):
        cfg = get_config(arch).reduced(n_layers=2, d_model=128, n_heads=4,
                                       vocab=512)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        stacked = {"embed": params["embed"],
                   "blocks": M.stack_blocks(params["blocks"],
                                            M.period_of(cfg)),
                   "head": params["head"]}
        b, plen, gen = 4, 16, 12
        prompts = jax.random.randint(jax.random.PRNGKey(1), (b, plen), 0,
                                     cfg.vocab)
        t0 = time.time()
        out = generate(cfg, stacked, prompts, gen, max_seq=plen + gen + 1)
        dt = time.time() - t0
        assert out.shape == (b, plen + gen)
        kinds = {l.mixer for l in cfg.layers}
        print(f"{arch:28s} mixers={sorted(kinds)} "
              f"{b}x{gen} tokens in {dt:5.1f}s "
              f"sample={list(np.asarray(out[0, -6:]))}")


if __name__ == "__main__":
    main()
