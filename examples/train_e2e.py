"""End-to-end training driver: data pipeline -> Runner API (pipeline
schedule -> AdamW) -> canonical checkpoint, with a verifying loss curve.

Any of the six schedule kinds works (``--schedule``); all lower through the
same table -> IR -> executor stack, so the loss curve is schedule-invariant
up to float reassociation.  ``--runtime`` picks the executor: the default
single-process reference executor, or ``spmd`` for the shard_map runtime
with in-mesh AdamW (needs ``--pp`` fake/real devices, e.g.
XLA_FLAGS=--xla_force_host_platform_device_count=2).

Default scale is CPU-friendly (~1M params, 60 steps, loss must drop);
``--full`` trains a ~100M-param model for 300 steps (the deliverable-scale
run; several hours on this 1-core container, minutes on real hardware).

  PYTHONPATH=src python examples/train_e2e.py
  PYTHONPATH=src python examples/train_e2e.py --schedule 1f1b-i --pp 2
  PYTHONPATH=src python examples/train_e2e.py --full
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.api import make_runner, save_state
from repro.configs import get_config
from repro.core.schedule import SCHEDULES
from repro.data import DataConfig, make_batches
from repro.models import model as M
from repro.optim import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--schedule", default="stp", choices=SCHEDULES)
    ap.add_argument("--pp", type=int, default=2)
    ap.add_argument("--runtime", choices=("pipeline", "spmd"),
                    default="pipeline")
    ap.add_argument("--ckpt", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    if args.full:   # ~100M params
        cfg = get_config("qwen3-4b").reduced(
            n_layers=8, d_model=768, n_heads=12, vocab=32768, d_ff=3072)
        steps, seq, batch, m = 300, 512, 16, 4
    else:
        cfg = get_config("qwen3-4b").reduced(
            n_layers=4, d_model=128, n_heads=4, vocab=512)
        steps, seq, batch, m = 30, 64, 8, 4
    if args.schedule == "1f1b-i" and m % args.pp:
        # 1F1B-I needs m % p == 0; keep batch % m == 0 while adjusting.
        cands = [k for k in range(args.pp, batch + 1, args.pp)
                 if batch % k == 0]
        if not cands:
            raise SystemExit(
                f"1f1b-i with pp={args.pp}: no microbatch count that is a "
                f"multiple of pp and divides global batch {batch}")
        m = min(cands, key=lambda k: abs(k - m))
    n_params = sum(x.size for x in jax.tree.leaves(
        M.init_params(jax.random.PRNGKey(0), cfg)))
    print(f"training {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{steps} steps, {args.schedule} schedule p={args.pp} m={m} "
          f"({args.runtime})")

    oc = OptConfig(lr=3e-3, warmup_steps=max(2, steps // 20),
                   total_steps=steps)
    dc = DataConfig(seq_len=seq, global_batch=batch, microbatches=m)
    runner = make_runner(args.runtime, cfg, oc, dc, schedule=args.schedule,
                         pp=args.pp)
    state = runner.init_state(M.init_params(jax.random.PRNGKey(0), cfg))

    losses = []
    t0 = time.time()
    for i, raw in enumerate(make_batches(cfg, dc, steps)):
        batch_arrs = {k: jnp.asarray(v) for k, v in raw.items()}
        state, metrics = runner.step(state, batch_arrs)
        losses.append(float(metrics["loss"]))
        if i % max(1, steps // 12) == 0:
            tok_s = batch * seq * (i + 1) / (time.time() - t0)
            print(f"step {i:4d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['gnorm']):.2f} tok/s {tok_s:,.0f}",
                  flush=True)

    save_state(args.ckpt, state,
               extra={"arch": cfg.name, "final_loss": losses[-1]})
    first = sum(losses[:5]) / 5
    last = sum(losses[-5:]) / 5
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'OK: decreased' if last < first else 'WARN: flat'}); "
          f"checkpoint at {args.ckpt}")
    assert last < first, "training did not reduce loss"


if __name__ == "__main__":
    main()
